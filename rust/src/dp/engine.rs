//! Worker pool: per-thread PJRT runtimes computing gradients on shards.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, ensure, Result};

use super::allreduce::{reduce_mean, Algorithm};
use crate::data::Batch;
use crate::manifest::Manifest;
use crate::runtime::{Input, Runtime};

/// Which training phase's artifact a step should execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// Pre-switch: `full_grads` (base only).
    Full,
    /// Warmup: `warmup_grads` (base + LoRA jointly, paper §3.3).
    Warmup,
    /// Post-freeze: `lora_grads` (base backward DCE'd).
    LoraOnly,
}

impl StepMode {
    fn artifact(self) -> &'static str {
        match self {
            StepMode::Full => "full_grads",
            StepMode::Warmup => "warmup_grads",
            StepMode::LoraOnly => "lora_grads",
        }
    }

    fn needs_lora(self) -> bool {
        !matches!(self, StepMode::Full)
    }
}

/// All-reduced gradients + averaged scalars for one global step.
#[derive(Debug, Clone)]
pub struct GradResult {
    pub d_base: Option<Vec<f32>>,
    pub d_lora: Option<Vec<f32>>,
    /// Mean loss across workers (each already batch-mean).
    pub loss: f64,
    /// Total top-1 hits across all shards.
    pub correct: f64,
    /// Samples processed this step.
    pub samples: usize,
    /// Wall seconds spent inside PJRT execute, summed over workers
    /// (= GPU-seconds analogue for the throughput accounting).
    pub execute_seconds: f64,
}

struct Job {
    mode: Option<StepMode>, // None => eval
    eval_lora: bool,
    base: Arc<Vec<f32>>,
    lora: Option<Arc<Vec<f32>>>,
    acfg: Option<Arc<Vec<f32>>>,
    batch: Batch,
}

struct WorkerOut {
    worker: usize,
    d_base: Option<Vec<f32>>,
    d_lora: Option<Vec<f32>>,
    loss: f32,
    correct: f32,
    execute_seconds: f64,
}

/// Execute one job on a runtime (shared by threaded workers and the
/// sequential fallback). Takes borrowed slices so the sequential path pays
/// zero parameter copies per step (perf pass, EXPERIMENTS.md §Perf).
#[allow(clippy::too_many_arguments)]
fn run_job(
    rt: &mut Runtime,
    manifest: &Manifest,
    mode: Option<StepMode>,
    eval_lora: bool,
    base: &[f32],
    lora: Option<(&[f32], &[f32])>,
    batch: &Batch,
) -> Result<WorkerOut> {
    let c = &manifest.config;
    let img_shape = [
        c.batch_size as i64,
        c.image_size as i64,
        c.image_size as i64,
        c.in_channels as i64,
    ];
    ensure!(
        batch.labels.len() == c.batch_size,
        "batch size {} != artifact batch {}",
        batch.labels.len(),
        c.batch_size
    );
    let name = match mode {
        Some(m) => m.artifact(),
        None if eval_lora => "eval_lora",
        None => "eval_full",
    };
    let needs_lora = mode.map(|m| m.needs_lora()).unwrap_or(eval_lora);
    let exe = rt.artifact(manifest, name)?;

    let base_shape = [manifest.base.size as i64];
    let lora_shape = [manifest.lora.size as i64];
    let acfg_shape = [manifest.adapter_cfg_size as i64];
    let lab_shape = [c.batch_size as i64];

    let mut inputs: Vec<Input<'_>> = vec![Input::f32(base, &base_shape)];
    if needs_lora {
        let (lora, acfg) = lora.ok_or_else(|| anyhow!("mode needs lora params"))?;
        inputs.push(Input::f32(lora, &lora_shape));
        inputs.push(Input::f32(acfg, &acfg_shape));
    }
    inputs.push(Input::f32(&batch.images, &img_shape));
    inputs.push(Input::i32(&batch.labels, &lab_shape));

    let t0 = std::time::Instant::now();
    let outs = exe.run(&inputs)?;
    let execute_seconds = t0.elapsed().as_secs_f64();

    // output order per manifest: grads.., loss, correct
    let (d_base, d_lora, loss, correct) = match mode {
        Some(StepMode::Full) => (Some(outs[0].clone()), None, outs[1][0], outs[2][0]),
        Some(StepMode::Warmup) => (
            Some(outs[0].clone()),
            Some(outs[1].clone()),
            outs[2][0],
            outs[3][0],
        ),
        Some(StepMode::LoraOnly) => (None, Some(outs[0].clone()), outs[1][0], outs[2][0]),
        None => (None, None, outs[0][0], outs[1][0]),
    };
    Ok(WorkerOut { worker: 0, d_base, d_lora, loss, correct, execute_seconds })
}

enum WorkerMsg {
    Job(Box<Job>),
    /// Compile artifacts now (phase change) so the next step's timing is
    /// clean of compilation cost.
    Precompile(Vec<&'static str>),
    Shutdown,
}

struct WorkerHandle {
    tx: mpsc::Sender<WorkerMsg>,
    join: Option<JoinHandle<()>>,
}

/// The data-parallel gradient engine: leader-side API over N workers.
pub struct GradEngine {
    manifest: Arc<Manifest>,
    workers: Vec<WorkerHandle>,
    results_rx: mpsc::Receiver<Result<WorkerOut>>,
    results_tx: mpsc::Sender<Result<WorkerOut>>,
    /// Sequential fallback runtime (also used when `workers == 0`).
    local: Option<Runtime>,
    algorithm: Algorithm,
    threaded: bool,
    n_workers: usize,
}

impl GradEngine {
    /// Spin up `workers` threads (each compiling its own executables) or a
    /// single sequential runtime when `threaded` is false.
    pub fn new(
        manifest: Arc<Manifest>,
        workers: usize,
        threaded: bool,
        algorithm: Algorithm,
    ) -> Result<Self> {
        ensure!(workers >= 1, "need at least one worker");
        let (results_tx, results_rx) = mpsc::channel();
        let mut engine = Self {
            manifest: manifest.clone(),
            workers: Vec::new(),
            results_rx,
            results_tx,
            local: None,
            algorithm,
            threaded: threaded && workers > 1,
            n_workers: workers,
        };
        if engine.threaded {
            for w in 0..workers {
                engine.spawn_worker(w)?;
            }
        } else {
            // artifacts compile lazily on first use: a baseline run never
            // pays for the LoRA artifacts, and a PreLoRA run amortizes the
            // warmup/lora compiles to the epoch where the phase starts
            // (perf pass iteration 3 — eager preload cost ~100s/run here)
            engine.local = Some(Runtime::new()?);
        }
        Ok(engine)
    }

    fn spawn_worker(&mut self, id: usize) -> Result<()> {
        let (tx, rx) = mpsc::channel::<WorkerMsg>();
        let results = self.results_tx.clone();
        let manifest = self.manifest.clone();
        let join = std::thread::Builder::new()
            .name(format!("dp-worker-{id}"))
            .spawn(move || {
                // each worker owns its own PJRT client (not Send)
                let mut rt = match Runtime::new() {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = results.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        WorkerMsg::Job(job) => {
                            let lora = match (&job.lora, &job.acfg) {
                                (Some(l), Some(a)) => Some((l.as_slice(), a.as_slice())),
                                _ => None,
                            };
                            let out = run_job(
                                &mut rt,
                                &manifest,
                                job.mode,
                                job.eval_lora,
                                &job.base,
                                lora,
                                &job.batch,
                            )
                            .map(|mut o| {
                                o.worker = id;
                                o
                            });
                            if results.send(out).is_err() {
                                break;
                            }
                        }
                        WorkerMsg::Precompile(names) => {
                            for n in names {
                                if let Err(e) = rt.artifact(&manifest, n) {
                                    let _ = results.send(Err(e));
                                }
                            }
                        }
                        WorkerMsg::Shutdown => break,
                    }
                }
            })?;
        self.workers.push(WorkerHandle { tx, join: Some(join) });
        Ok(())
    }

    pub fn worker_count(&self) -> usize {
        self.n_workers
    }

    /// Compile artifacts ahead of their first use (called by the trainer
    /// at phase changes, outside the epoch timing).
    pub fn precompile(&mut self, names: &[&'static str]) -> Result<()> {
        if self.threaded {
            for w in &self.workers {
                w.tx
                    .send(WorkerMsg::Precompile(names.to_vec()))
                    .map_err(|_| anyhow!("worker hung up"))?;
            }
        } else if let Some(rt) = self.local.as_mut() {
            for n in names {
                rt.artifact(&self.manifest, n)?;
            }
        }
        Ok(())
    }

    /// Compute all-reduced gradients for one global step. `batches` must
    /// hold exactly one local batch per worker.
    pub fn compute(
        &mut self,
        mode: StepMode,
        base: &[f32],
        lora: Option<(&[f32], &[f32])>,
        batches: Vec<Batch>,
    ) -> Result<GradResult> {
        ensure!(batches.len() == self.n_workers, "one batch per worker required");
        let outs = self.dispatch(Some(mode), false, base, lora, batches)?;
        let samples = self.manifest.config.batch_size * outs.len();
        let mut loss = 0.0;
        let mut correct = 0.0;
        let mut exec = 0.0;
        let mut base_bufs = Vec::new();
        let mut lora_bufs = Vec::new();
        for o in outs {
            loss += o.loss as f64;
            correct += o.correct as f64;
            exec += o.execute_seconds;
            if let Some(b) = o.d_base {
                base_bufs.push(b);
            }
            if let Some(l) = o.d_lora {
                lora_bufs.push(l);
            }
        }
        let n = self.n_workers as f64;
        let d_base = if base_bufs.is_empty() {
            None
        } else {
            reduce_mean(self.algorithm, &mut base_bufs);
            Some(base_bufs.swap_remove(0))
        };
        let d_lora = if lora_bufs.is_empty() {
            None
        } else {
            reduce_mean(self.algorithm, &mut lora_bufs);
            Some(lora_bufs.swap_remove(0))
        };
        Ok(GradResult {
            d_base,
            d_lora,
            loss: loss / n,
            correct,
            samples,
            execute_seconds: exec,
        })
    }

    /// Evaluate loss/accuracy over a batch list (round-robin sharding).
    /// Returns (mean loss, accuracy, samples).
    pub fn evaluate(
        &mut self,
        base: &[f32],
        lora: Option<(&[f32], &[f32])>,
        batches: Vec<Batch>,
    ) -> Result<(f64, f64, usize)> {
        ensure!(!batches.is_empty(), "no eval batches");
        let bsz = self.manifest.config.batch_size;
        let n_batches = batches.len();
        let mut loss = 0.0;
        let mut correct = 0.0;
        // dispatch in waves of worker-count
        let mut batches = batches;
        while !batches.is_empty() {
            let take = batches.len().min(self.n_workers.max(1));
            let wave: Vec<Batch> = batches.drain(..take).collect();
            let outs = self.dispatch(None, lora.is_some(), base, lora, wave)?;
            for o in outs {
                loss += o.loss as f64;
                correct += o.correct as f64;
            }
        }
        let samples = n_batches * bsz;
        Ok((loss / n_batches as f64, correct / samples as f64, samples))
    }

    fn dispatch(
        &mut self,
        mode: Option<StepMode>,
        eval_lora: bool,
        base: &[f32],
        lora: Option<(&[f32], &[f32])>,
        batches: Vec<Batch>,
    ) -> Result<Vec<WorkerOut>> {
        let n = batches.len();
        if self.threaded {
            // one shared snapshot of the parameters per step (inherent to
            // fan-out: workers outlive the borrow)
            let base = Arc::new(base.to_vec());
            let (lora_arc, acfg_arc) = match lora {
                Some((l, a)) => (Some(Arc::new(l.to_vec())), Some(Arc::new(a.to_vec()))),
                None => (None, None),
            };
            for (w, batch) in batches.into_iter().enumerate() {
                let job = Job {
                    mode,
                    eval_lora,
                    base: base.clone(),
                    lora: lora_arc.clone(),
                    acfg: acfg_arc.clone(),
                    batch,
                };
                self.workers[w]
                    .tx
                    .send(WorkerMsg::Job(Box::new(job)))
                    .map_err(|_| anyhow!("worker {w} hung up"))?;
            }
            let mut outs = Vec::with_capacity(n);
            for _ in 0..n {
                outs.push(self.results_rx.recv().map_err(|_| anyhow!("workers died"))??);
            }
            // deterministic reduction order regardless of completion order
            outs.sort_by_key(|o| o.worker);
            Ok(outs)
        } else {
            // sequential path: zero-copy borrows straight into the runtime
            let rt = self.local.as_mut().expect("local runtime");
            let mut outs = Vec::with_capacity(n);
            for (w, batch) in batches.iter().enumerate() {
                let mut o = run_job(rt, &self.manifest, mode, eval_lora, base, lora, batch)?;
                o.worker = w;
                outs.push(o);
            }
            Ok(outs)
        }
    }
}

impl Drop for GradEngine {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, EpochLoader, SynthSpec};
    use std::path::PathBuf;

    fn micro() -> Arc<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-micro");
        Arc::new(Manifest::load(dir).expect("run `make artifacts` first"))
    }

    fn data(m: &Manifest, samples: usize) -> Dataset {
        let c = &m.config;
        Dataset::generate(&SynthSpec {
            samples,
            image_size: c.image_size,
            channels: c.in_channels,
            num_classes: c.num_classes,
            noise: 0.3,
            phase_jitter: true,
            seed: 11,
        })
    }

    #[test]
    fn sequential_full_step_produces_grads() {
        let m = micro();
        let d = data(&m, 64);
        let loader = EpochLoader::new(m.config.batch_size, 1, 0);
        let mut eng = GradEngine::new(m.clone(), 1, false, Algorithm::Naive).unwrap();
        let base = m.load_init_base().unwrap();
        let batches = loader.step_batches(&d, 0, 0);
        let r = eng.compute(StepMode::Full, &base, None, batches).unwrap();
        let g = r.d_base.unwrap();
        assert_eq!(g.len(), m.base.size);
        assert!(crate::tensor::l2_norm(&g) > 0.0);
        assert!(r.loss.is_finite() && r.loss > 0.0);
        assert!(r.d_lora.is_none());
    }

    #[test]
    fn threaded_matches_sequential_bitwise() {
        // The DP equivalence invariant: threading must not change numerics
        // (deterministic shard order + ordered reduction).
        let m = micro();
        let d = data(&m, 64);
        let workers = 2;
        let loader = EpochLoader::new(m.config.batch_size, workers, 0);
        let base = m.load_init_base().unwrap();
        let batches = loader.step_batches(&d, 0, 0);

        let mut seq = GradEngine::new(m.clone(), workers, false, Algorithm::Tree).unwrap();
        let r1 = seq.compute(StepMode::Full, &base, None, batches.clone()).unwrap();
        let mut thr = GradEngine::new(m.clone(), workers, true, Algorithm::Tree).unwrap();
        let r2 = thr.compute(StepMode::Full, &base, None, batches).unwrap();

        assert_eq!(r1.d_base.as_ref().unwrap(), r2.d_base.as_ref().unwrap());
        assert_eq!(r1.loss, r2.loss);
        assert_eq!(r1.correct, r2.correct);
    }

    #[test]
    fn lora_step_leaves_base_gradient_absent() {
        let m = micro();
        let d = data(&m, 32);
        let loader = EpochLoader::new(m.config.batch_size, 1, 0);
        let mut eng = GradEngine::new(m.clone(), 1, false, Algorithm::Naive).unwrap();
        let mut base = m.load_init_base().unwrap();
        // the zero-init head makes every trunk gradient vanish at init
        // (d pooled = head.w @ d logits = 0); randomize it as real training
        // would have by the time the switch happens
        let mut rng = crate::tensor::Pcg64::new(3);
        for t in &m.base.tensors {
            if t.module == "head" && t.is_weight_matrix() {
                rng.fill_normal(&mut base[t.offset..t.offset + t.size], 0.05);
            }
        }
        // uniform rank-2 adapters, A random / B zero
        let mut lora = vec![0.0f32; m.lora.size];
        for t in &m.lora.tensors {
            if t.module == "lora_a" {
                rng.fill_normal(&mut lora[t.offset..t.offset + t.size], 0.02);
            }
        }
        let modules: Vec<String> =
            crate::manifest::ADAPTED_MODULES.iter().map(|s| s.to_string()).collect();
        let assign = crate::rank::uniform_ranks(&modules, m.config.depth, 2);
        let acfg = crate::rank::build_adapter_cfg(&m, &assign, m.config.lora_alpha).unwrap();
        let batches = loader.step_batches(&d, 0, 0);
        let r = eng
            .compute(StepMode::LoraOnly, &base, Some((&lora, &acfg.values)), batches)
            .unwrap();
        assert!(r.d_base.is_none());
        let dl = r.d_lora.unwrap();
        assert_eq!(dl.len(), m.lora.size);
        assert!(crate::tensor::l2_norm(&dl) > 0.0);
    }

    #[test]
    fn evaluate_returns_chance_accuracy_at_init() {
        let m = micro();
        let d = data(&m, 64);
        let loader = EpochLoader::new(m.config.batch_size, 1, 0);
        let mut eng = GradEngine::new(m.clone(), 1, false, Algorithm::Naive).unwrap();
        let base = m.load_init_base().unwrap();
        let (loss, acc, samples) = eng.evaluate(&base, None, loader.eval_batches(&d)).unwrap();
        assert_eq!(samples, 64);
        // zero head => exactly ln(K) loss, accuracy near chance
        assert!((loss - (m.config.num_classes as f64).ln()).abs() < 0.05);
        assert!(acc <= 0.5);
    }
}
