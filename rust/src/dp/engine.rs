//! Worker pool: per-thread PJRT runtimes computing gradients on shards.

use std::panic::AssertUnwindSafe;

use anyhow::{anyhow, ensure, Result};

use crate::sync::{mpsc, thread, Arc};

use super::allreduce::{reduce_owned, reduce_scatter, Algorithm, BucketPlan, Reduced};
use crate::data::Batch;
use crate::faults::ComputeFault;
use crate::manifest::Manifest;
use crate::runtime::{Input, Runtime};

/// Which training phase's artifact a step should execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// Pre-switch: `full_grads` (base only).
    Full,
    /// Warmup: `warmup_grads` (base + LoRA jointly, paper §3.3).
    Warmup,
    /// Post-freeze: `lora_grads` (base backward DCE'd).
    LoraOnly,
}

impl StepMode {
    fn artifact(self) -> &'static str {
        match self {
            StepMode::Full => "full_grads",
            StepMode::Warmup => "warmup_grads",
            StepMode::LoraOnly => "lora_grads",
        }
    }

    fn needs_lora(self) -> bool {
        !matches!(self, StepMode::Full)
    }
}

/// All-reduced gradients + averaged scalars for one global step. The
/// gradient buffers are [`Reduced`]: replicated full vectors on the
/// classic path, per-worker owned partitions on the ZeRO path — bitwise
/// the same values either way.
#[derive(Debug, Clone)]
pub struct GradResult {
    pub d_base: Option<Reduced>,
    pub d_lora: Option<Reduced>,
    /// Mean loss across workers (each already batch-mean).
    pub loss: f64,
    /// Total top-1 hits across all shards.
    pub correct: f64,
    /// Samples processed this step.
    pub samples: usize,
    /// Wall seconds spent inside PJRT execute, summed over workers
    /// (= GPU-seconds analogue for the throughput accounting).
    pub execute_seconds: f64,
}

impl GradResult {
    /// Gradient bytes a single rank retains after the reduce: everything
    /// when the layout is replicated, the largest owned partition per
    /// buffer under ZeRO-2 (the number `MemoryBreakdown.grad_bytes`
    /// reports per rank).
    pub fn grad_bytes_per_rank(&self) -> usize {
        let elems = |g: &Option<Reduced>| g.as_ref().map_or(0, Reduced::per_rank_elems);
        (elems(&self.d_base) + elems(&self.d_lora)) * 4
    }

    /// Gradient bytes across the whole step, layout-independent (the
    /// replicated footprint; `grad_bytes_per_rank` times the partition
    /// count up to chunk rounding).
    pub fn grad_total_bytes(&self) -> usize {
        let elems = |g: &Option<Reduced>| g.as_ref().map_or(0, Reduced::len);
        (elems(&self.d_base) + elems(&self.d_lora)) * 4
    }
}

/// Raw per-worker gradients of one global step (worker order), scalars
/// already aggregated. Produced by [`GradEngine::collect`]; the reduce
/// stage (or [`StepOutputs::reduce`]) turns it into a [`GradResult`].
#[derive(Debug)]
pub struct StepOutputs {
    /// One base-gradient buffer per worker that produced one.
    pub base_grads: Vec<Vec<f32>>,
    /// One LoRA-gradient buffer per worker that produced one.
    pub lora_grads: Vec<Vec<f32>>,
    /// Mean loss across workers.
    pub loss: f64,
    /// Total top-1 hits across shards.
    pub correct: f64,
    /// Samples processed this step.
    pub samples: usize,
    /// Wall seconds inside PJRT execute, summed over workers.
    pub execute_seconds: f64,
}

impl StepOutputs {
    /// All-reduce both buffer sets inline (the non-overlapped path).
    pub fn reduce(self, algorithm: Algorithm) -> GradResult {
        GradResult {
            d_base: reduce_owned(algorithm, self.base_grads).map(Reduced::Full),
            d_lora: reduce_owned(algorithm, self.lora_grads).map(Reduced::Full),
            loss: self.loss,
            correct: self.correct,
            samples: self.samples,
            execute_seconds: self.execute_seconds,
        }
    }

    /// Reduce-scatter both buffer sets into `parts` owned partitions
    /// (ZeRO-2): each worker keeps only its chunk of the mean gradient,
    /// the per-worker full buffers are consumed by the reduce, and no
    /// replicated mean vector is materialized. `parts <= 1` degrades to
    /// the replicated [`reduce`](Self::reduce) — both produce
    /// bitwise-identical values (see
    /// [`reduce_scatter`](crate::dp::reduce_scatter)).
    pub fn reduce_sharded(self, algorithm: Algorithm, parts: usize) -> GradResult {
        if parts <= 1 {
            return self.reduce(algorithm);
        }
        GradResult {
            d_base: reduce_scatter(algorithm, self.base_grads, parts).map(Reduced::Sharded),
            d_lora: reduce_scatter(algorithm, self.lora_grads, parts).map(Reduced::Sharded),
            loss: self.loss,
            correct: self.correct,
            samples: self.samples,
            execute_seconds: self.execute_seconds,
        }
    }
}

/// Which of a step's two gradient spaces a bucket belongs to. `Ord` so it
/// can key the accumulator's `BTreeMap` (PL001: no order-nondeterministic
/// containers on the reduce path).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum GradSpace {
    Base,
    Lora,
}

/// One worker's gradient slice for one bucket, published as soon as that
/// slice of the backward output is available (rather than waiting for the
/// whole step to collect). `bucket` indexes the space's [`BucketPlan`].
#[derive(Debug)]
pub struct BucketMsg {
    pub space: GradSpace,
    pub bucket: usize,
    pub worker: usize,
    /// The bucket's start offset within the space (for the positional
    /// ring schedule).
    pub lo: usize,
    /// The space's full length.
    pub full_len: usize,
    pub data: Vec<f32>,
}

/// Everything that can travel the bucket queue: worker-published bucket
/// slices plus the reduce stage's lifecycle signals. Workers only ever
/// send `Bucket` — [`BucketTx`] cannot forge the control variants, whose
/// senders stay with the stage that owns the accumulator thread (every
/// spawned thread has a shutdown story — PL005). The enum itself is
/// public only because [`BucketTx::channel`] hands the receiving half to
/// tests.
pub enum BucketCtrl {
    Bucket(BucketMsg),
    /// Epoch barrier: drop any partial accumulation an aborted step left
    /// behind before the next epoch starts publishing (`epoch_route`).
    Reset,
    /// Terminate the accumulator even while other senders are still
    /// alive, so `ReduceStage::drop` can join the thread regardless of
    /// drop order (the engine may still hold route clones).
    Shutdown,
}

/// The bucket queue's receiver is gone: the reduce stage is shutting down
/// or has already failed the step. Publishing is pointless but harmless.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketQueueClosed;

/// Sending half of the bounded bucket queue. A newtype over the raw
/// channel so workers can only publish bucket slices — the lifecycle
/// signals ([`BucketCtrl::Reset`] / [`BucketCtrl::Shutdown`]) stay with
/// the reduce stage that owns the accumulator thread.
#[derive(Clone)]
pub struct BucketTx(mpsc::SyncSender<BucketCtrl>);

impl BucketTx {
    /// A bounded queue: throttles publishers without ever filling faster
    /// than the accumulator drains. Public so tests can build a
    /// [`BucketRoute`] and drive the publish path directly; the receiving
    /// half stays crate-internal (only the reduce stage drains it).
    pub fn channel(bound: usize) -> (Self, mpsc::Receiver<BucketCtrl>) {
        let (tx, rx) = mpsc::sync_channel(bound);
        (Self(tx), rx)
    }

    /// Publish one bucket slice (blocks while the queue is full).
    pub fn send(&self, msg: BucketMsg) -> Result<(), BucketQueueClosed> {
        self.0.send(BucketCtrl::Bucket(msg)).map_err(|_| BucketQueueClosed)
    }

    /// Clear the accumulator's partial state at an epoch barrier.
    pub(crate) fn reset(&self) -> Result<(), BucketQueueClosed> {
        self.0.send(BucketCtrl::Reset).map_err(|_| BucketQueueClosed)
    }

    /// Ask the accumulator thread to exit now (overrides live senders).
    pub(crate) fn shutdown(&self) -> Result<(), BucketQueueClosed> {
        self.0.send(BucketCtrl::Shutdown).map_err(|_| BucketQueueClosed)
    }
}

/// Where workers publish per-bucket gradients: the bucket layouts of the
/// live spaces (`None` = that space is not bucketed this epoch) plus the
/// bounded queue the reduce stage's accumulator thread drains. Cloned
/// into each job so every worker thread owns its own sender handle.
#[derive(Clone)]
pub struct BucketRoute {
    pub base: Option<Arc<BucketPlan>>,
    pub lora: Option<Arc<BucketPlan>>,
    pub tx: BucketTx,
}

/// Slice a worker's gradient buffers per the route's bucket plans and
/// publish them in (space, bucket-index) order; published buffers are
/// stripped from the output so only scalars flow through the results
/// channel. Send errors are ignored: a gone receiver means the leader is
/// already failing the step. A length mismatch is a logic bug — panicking
/// here drops the worker's results sender, which surfaces as a collect
/// error leader-side instead of a silent bucket-wait hang.
fn publish_buckets(route: &BucketRoute, mut out: WorkerOut) -> WorkerOut {
    let worker = out.worker;
    let publish = |space: GradSpace, plan: &BucketPlan, d: Vec<f32>| {
        assert_eq!(d.len(), plan.len, "{space:?} gradient length vs bucket plan");
        for (i, b) in plan.buckets.iter().enumerate() {
            let _ = route.tx.send(BucketMsg {
                space,
                bucket: i,
                worker,
                lo: b.lo,
                full_len: plan.len,
                data: d[b.lo..b.hi].to_vec(),
            });
        }
    };
    if let Some(plan) = route.base.as_deref() {
        if let Some(d) = out.d_base.take() {
            publish(GradSpace::Base, plan, d);
        }
    }
    if let Some(plan) = route.lora.as_deref() {
        if let Some(d) = out.d_lora.take() {
            publish(GradSpace::Lora, plan, d);
        }
    }
    out
}

/// Best-effort text of a caught panic payload (`&str` from `panic!("..")`,
/// `String` from `panic!("{x}")`, opaque otherwise).
fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

struct Job {
    mode: Option<StepMode>, // None => eval
    eval_lora: bool,
    base: Arc<Vec<f32>>,
    lora: Option<Arc<Vec<f32>>>,
    acfg: Option<Arc<Vec<f32>>>,
    batch: Batch,
    /// Bucketed-sync route for this step (cloned per job; `None` =
    /// whole-buffer gradients flow back through the results channel).
    route: Option<BucketRoute>,
    /// Injected fault for this worker's slice of the step (`None` on
    /// every job outside adversity testing).
    fault: Option<ComputeFault>,
}

struct WorkerOut {
    worker: usize,
    d_base: Option<Vec<f32>>,
    d_lora: Option<Vec<f32>>,
    loss: f32,
    correct: f32,
    execute_seconds: f64,
}

/// Execute one job on a runtime (shared by threaded workers and the
/// sequential fallback). Takes borrowed slices so the sequential path pays
/// zero parameter copies per step (perf pass, EXPERIMENTS.md §Perf).
#[allow(clippy::too_many_arguments)]
fn run_job(
    rt: &mut Runtime,
    manifest: &Manifest,
    mode: Option<StepMode>,
    eval_lora: bool,
    base: &[f32],
    lora: Option<(&[f32], &[f32])>,
    batch: &Batch,
) -> Result<WorkerOut> {
    let c = &manifest.config;
    let img_shape = [
        c.batch_size as i64,
        c.image_size as i64,
        c.image_size as i64,
        c.in_channels as i64,
    ];
    ensure!(
        batch.labels.len() == c.batch_size,
        "batch size {} != artifact batch {}",
        batch.labels.len(),
        c.batch_size
    );
    let name = match mode {
        Some(m) => m.artifact(),
        None if eval_lora => "eval_lora",
        None => "eval_full",
    };
    let needs_lora = mode.map(|m| m.needs_lora()).unwrap_or(eval_lora);
    let exe = rt.artifact(manifest, name)?;

    let base_shape = [manifest.base.size as i64];
    let lora_shape = [manifest.lora.size as i64];
    let acfg_shape = [manifest.adapter_cfg_size as i64];
    let lab_shape = [c.batch_size as i64];

    let mut inputs: Vec<Input<'_>> = vec![Input::f32(base, &base_shape)];
    if needs_lora {
        let (lora, acfg) = lora.ok_or_else(|| anyhow!("mode needs lora params"))?;
        inputs.push(Input::f32(lora, &lora_shape));
        inputs.push(Input::f32(acfg, &acfg_shape));
    }
    inputs.push(Input::f32(&batch.images, &img_shape));
    inputs.push(Input::i32(&batch.labels, &lab_shape));

    let t0 = std::time::Instant::now();
    let outs = exe.run(&inputs)?;
    let execute_seconds = t0.elapsed().as_secs_f64();

    // output order per manifest: grads.., loss, correct
    let (d_base, d_lora, loss, correct) = match mode {
        Some(StepMode::Full) => (Some(outs[0].clone()), None, outs[1][0], outs[2][0]),
        Some(StepMode::Warmup) => (
            Some(outs[0].clone()),
            Some(outs[1].clone()),
            outs[2][0],
            outs[3][0],
        ),
        Some(StepMode::LoraOnly) => (None, Some(outs[0].clone()), outs[1][0], outs[2][0]),
        None => (None, None, outs[0][0], outs[1][0]),
    };
    Ok(WorkerOut { worker: 0, d_base, d_lora, loss, correct, execute_seconds })
}

enum WorkerMsg {
    Job(Box<Job>),
    /// Compile artifacts now (phase change) so the next step's timing is
    /// clean of compilation cost.
    Precompile(Vec<&'static str>),
    Shutdown,
}

struct WorkerHandle {
    tx: mpsc::Sender<WorkerMsg>,
    join: Option<thread::JoinHandle<()>>,
}

/// The data-parallel gradient engine: leader-side API over N workers.
pub struct GradEngine {
    manifest: Arc<Manifest>,
    workers: Vec<WorkerHandle>,
    results_rx: mpsc::Receiver<Result<WorkerOut>>,
    results_tx: mpsc::Sender<Result<WorkerOut>>,
    /// Sequential fallback runtime (also used when `workers == 0`).
    local: Option<Runtime>,
    algorithm: Algorithm,
    threaded: bool,
    n_workers: usize,
    /// Worker results outstanding for a submitted-but-uncollected step.
    in_flight: usize,
    /// Parked outputs of a sequential-path submit (runs synchronously).
    parked: Option<Vec<WorkerOut>>,
    /// Bucketed-sync route for training steps (`None` = whole-buffer).
    route: Option<BucketRoute>,
    /// Per-worker injected faults armed for the NEXT submitted training
    /// step, then consumed by it (empty outside adversity testing — the
    /// hot path pays one `Vec::is_empty`-grade check per step).
    step_faults: Vec<Option<ComputeFault>>,
}

impl GradEngine {
    /// Spin up `workers` threads (each compiling its own executables) or a
    /// single sequential runtime when `threaded` is false.
    pub fn new(
        manifest: Arc<Manifest>,
        workers: usize,
        threaded: bool,
        algorithm: Algorithm,
    ) -> Result<Self> {
        ensure!(workers >= 1, "need at least one worker");
        // lint: allow(PL008): depth is capped by in_flight accounting —
        // the leader never has more than one outstanding job per worker,
        // so at most n_workers results queue here.
        let (results_tx, results_rx) = mpsc::channel();
        let mut engine = Self {
            manifest: manifest.clone(),
            workers: Vec::new(),
            results_rx,
            results_tx,
            local: None,
            algorithm,
            threaded: threaded && workers > 1,
            n_workers: workers,
            in_flight: 0,
            parked: None,
            route: None,
            step_faults: Vec::new(),
        };
        if engine.threaded {
            for w in 0..workers {
                engine.spawn_worker(w)?;
            }
        } else {
            // artifacts compile lazily on first use: a baseline run never
            // pays for the LoRA artifacts, and a PreLoRA run amortizes the
            // warmup/lora compiles to the epoch where the phase starts
            // (perf pass iteration 3 — eager preload cost ~100s/run here)
            engine.local = Some(Runtime::new()?);
        }
        Ok(engine)
    }

    fn spawn_worker(&mut self, id: usize) -> Result<()> {
        // lint: allow(PL008): worker inbox — the leader sends at most one
        // job per in-flight slot plus a final Shutdown, so depth ≤ 2.
        let (tx, rx) = mpsc::channel::<WorkerMsg>();
        let results = self.results_tx.clone();
        let manifest = self.manifest.clone();
        // lint: thread: joined — GradEngine::drop sends WorkerMsg::Shutdown
        // to every worker, then joins each handle.
        let join = thread::Builder::new()
            .name(format!("dp-worker-{id}"))
            .spawn(move || {
                // each worker owns its own PJRT client (not Send)
                let mut rt = match Runtime::new() {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = results.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        WorkerMsg::Job(job) => {
                            // A panicking job (artifact mismatch, bucket
                            // protocol bug) must reach the leader as an
                            // error on the results channel. Without the
                            // catch, the worker vanishes with its result
                            // unsent and the leader's recv_all waits
                            // forever — the engine's own results_tx clone
                            // keeps the channel open, so no disconnect
                            // error ever arrives (model-checked in
                            // tests/loom_bucket.rs).
                            let out = std::panic::catch_unwind(AssertUnwindSafe(|| {
                                // injected fault fires first: a straggler
                                // sleeps (then computes normally), an abort
                                // errors out, a panic unwinds into the catch
                                // above — all surface exactly like the real
                                // failure they model
                                if let Some(f) = &job.fault {
                                    f.fire()?;
                                }
                                let lora = match (&job.lora, &job.acfg) {
                                    (Some(l), Some(a)) => Some((l.as_slice(), a.as_slice())),
                                    _ => None,
                                };
                                run_job(
                                    &mut rt,
                                    &manifest,
                                    job.mode,
                                    job.eval_lora,
                                    &job.base,
                                    lora,
                                    &job.batch,
                                )
                                .map(|mut o| {
                                    o.worker = id;
                                    match job.route.as_ref() {
                                        // publish buckets as soon as this
                                        // worker's backward output is ready —
                                        // the reduce thread overlaps with the
                                        // other workers' still-running steps
                                        Some(route) => publish_buckets(route, o),
                                        None => o,
                                    }
                                })
                            }))
                            .unwrap_or_else(|p| {
                                Err(anyhow!("worker {id} panicked: {}", panic_message(&*p)))
                            });
                            if results.send(out).is_err() {
                                break;
                            }
                        }
                        WorkerMsg::Precompile(names) => {
                            for n in names {
                                if let Err(e) = rt.artifact(&manifest, n) {
                                    let _ = results.send(Err(e));
                                }
                            }
                        }
                        WorkerMsg::Shutdown => break,
                    }
                }
            })?;
        self.workers.push(WorkerHandle { tx, join: Some(join) });
        Ok(())
    }

    pub fn worker_count(&self) -> usize {
        self.n_workers
    }

    /// Compile artifacts ahead of their first use (called by the trainer
    /// at phase changes, outside the epoch timing).
    pub fn precompile(&mut self, names: &[&'static str]) -> Result<()> {
        if self.threaded {
            for w in &self.workers {
                w.tx
                    .send(WorkerMsg::Precompile(names.to_vec()))
                    .map_err(|_| anyhow!("worker hung up"))?;
            }
        } else if let Some(rt) = self.local.as_mut() {
            for n in names {
                rt.artifact(&self.manifest, n)?;
            }
        }
        Ok(())
    }

    pub fn algorithm(&self) -> Algorithm {
        self.algorithm
    }

    /// Install (or clear) the bucketed-sync route for subsequent training
    /// steps. Set by the pipeline at each epoch start — the epoch barrier
    /// guarantees no step is in flight, and re-deriving there is what
    /// picks up new bucket layouts after a `Repartition` event. With a
    /// route installed, workers publish per-bucket gradient slices to the
    /// route's queue as each backward completes and only scalars flow
    /// through the results channel.
    pub fn set_bucket_route(&mut self, route: Option<BucketRoute>) {
        debug_assert_eq!(self.in_flight, 0, "route change with a step in flight");
        self.route = route;
    }

    /// Arm per-worker injected faults for the next training step (index =
    /// worker id; consumed by that step's submit). Called by the pipeline
    /// before each submit when a fault plan is active; outside adversity
    /// testing the list stays empty and the step path is unchanged.
    pub fn set_step_faults(&mut self, faults: Vec<Option<ComputeFault>>) {
        debug_assert_eq!(self.in_flight, 0, "fault change with a step in flight");
        self.step_faults = faults;
    }

    /// Threaded fan-out: snapshot the parameters once, send one job per
    /// worker. Every successful send increments `in_flight`, so an error
    /// mid-loop leaves an exact count for [`drain`](Self::drain) /
    /// [`recv_all`](Self::recv_all) to flush.
    fn fan_out(
        &mut self,
        mode: Option<StepMode>,
        eval_lora: bool,
        base: &[f32],
        lora: Option<(&[f32], &[f32])>,
        batches: Vec<Batch>,
    ) -> Result<()> {
        // one shared snapshot of the parameters per step (inherent to
        // fan-out: workers outlive the borrow)
        let base = Arc::new(base.to_vec());
        let (lora_arc, acfg_arc) = match lora {
            Some((l, a)) => (Some(Arc::new(l.to_vec())), Some(Arc::new(a.to_vec()))),
            None => (None, None),
        };
        // eval jobs produce no gradients, so they never publish buckets —
        // and injected faults target training steps only
        let route = if mode.is_some() { self.route.clone() } else { None };
        let mut faults = if mode.is_some() {
            std::mem::take(&mut self.step_faults)
        } else {
            Vec::new()
        };
        for (w, batch) in batches.into_iter().enumerate() {
            let job = Job {
                mode,
                eval_lora,
                base: base.clone(),
                lora: lora_arc.clone(),
                acfg: acfg_arc.clone(),
                batch,
                route: route.clone(),
                fault: faults.get_mut(w).and_then(Option::take),
            };
            self.workers[w]
                .tx
                .send(WorkerMsg::Job(Box::new(job)))
                .map_err(|_| anyhow!("worker {w} hung up"))?;
            self.in_flight += 1;
        }
        Ok(())
    }

    /// Receive every outstanding result in deterministic worker order,
    /// consuming all of them even on error so nothing stays queued for the
    /// next step to trip over.
    fn recv_all(&mut self) -> Result<Vec<WorkerOut>> {
        let n = self.in_flight;
        let mut outs = Vec::with_capacity(n);
        let mut first_err = None;
        for _ in 0..n {
            match self.results_rx.recv() {
                Ok(Ok(o)) => outs.push(o),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("workers died"));
                    }
                    break; // channel closed: no more results coming
                }
            }
        }
        self.in_flight = 0;
        if let Some(e) = first_err {
            return Err(e);
        }
        // deterministic reduction order regardless of completion order
        outs.sort_by_key(|o| o.worker);
        Ok(outs)
    }

    /// Dispatch one global step to the workers without waiting for it.
    /// `batches` must hold exactly one local batch per worker; exactly one
    /// step may be in flight (synchronous SGD — step *k+1*'s inputs depend
    /// on step *k*'s update anyway). On the sequential fallback the step
    /// runs here and [`collect`](Self::collect) just hands it back.
    pub fn submit(
        &mut self,
        mode: StepMode,
        base: &[f32],
        lora: Option<(&[f32], &[f32])>,
        batches: Vec<Batch>,
    ) -> Result<()> {
        ensure!(self.in_flight == 0, "a step is already in flight");
        ensure!(batches.len() == self.n_workers, "one batch per worker required");
        let n = batches.len();
        if self.threaded {
            self.fan_out(Some(mode), false, base, lora, batches)?;
        } else {
            // sequential path: zero-copy borrows straight into the runtime,
            // executed eagerly (there is no background thread to defer to)
            let mut faults = std::mem::take(&mut self.step_faults);
            let rt = self
                .local
                .as_mut()
                .ok_or_else(|| anyhow!("sequential engine has no local runtime"))?;
            let mut outs = Vec::with_capacity(n);
            for (w, batch) in batches.iter().enumerate() {
                // the same fault surface as the threaded path: a panic
                // fault unwinds into the catch and comes back as the
                // worker-panicked error instead of crashing the leader
                if let Some(f) = faults.get_mut(w).and_then(Option::take) {
                    std::panic::catch_unwind(AssertUnwindSafe(|| f.fire())).unwrap_or_else(
                        |p| Err(anyhow!("worker {w} panicked: {}", panic_message(&*p))),
                    )?;
                }
                let mut o = run_job(rt, &self.manifest, Some(mode), false, base, lora, batch)?;
                o.worker = w;
                if let Some(route) = self.route.as_ref() {
                    o = publish_buckets(route, o);
                }
                outs.push(o);
            }
            self.parked = Some(outs);
            self.in_flight = n;
        }
        Ok(())
    }

    /// Wait for the in-flight step and return its raw per-worker outputs
    /// in deterministic worker order.
    pub fn collect(&mut self) -> Result<StepOutputs> {
        ensure!(self.in_flight > 0, "no step in flight");
        let outs = match self.parked.take() {
            Some(outs) => {
                self.in_flight = 0;
                outs
            }
            None => self.recv_all()?,
        };
        let samples = self.manifest.config.batch_size * outs.len();
        let mut loss = 0.0;
        let mut correct = 0.0;
        let mut exec = 0.0;
        let mut base_grads = Vec::new();
        let mut lora_grads = Vec::new();
        for o in outs {
            loss += o.loss as f64;
            correct += o.correct as f64;
            exec += o.execute_seconds;
            if let Some(b) = o.d_base {
                base_grads.push(b);
            }
            if let Some(l) = o.d_lora {
                lora_grads.push(l);
            }
        }
        Ok(StepOutputs {
            base_grads,
            lora_grads,
            loss: loss / self.n_workers as f64,
            correct,
            samples,
            execute_seconds: exec,
        })
    }

    /// Discard any in-flight step (error-path barrier: nothing may stay
    /// queued across a phase switch or into the next epoch).
    pub fn drain(&mut self) {
        // sequential-path results are parked locally, nothing is queued
        if self.parked.take().is_some() {
            self.in_flight = 0;
            return;
        }
        while self.in_flight > 0 {
            if self.results_rx.recv().is_err() {
                break;
            }
            self.in_flight -= 1;
        }
        self.in_flight = 0;
    }

    /// Compute all-reduced gradients for one global step (submit + collect
    /// + inline reduce — the serial reference path).
    pub fn compute(
        &mut self,
        mode: StepMode,
        base: &[f32],
        lora: Option<(&[f32], &[f32])>,
        batches: Vec<Batch>,
    ) -> Result<GradResult> {
        self.submit(mode, base, lora, batches)?;
        let outs = self.collect()?;
        Ok(outs.reduce(self.algorithm))
    }

    /// Evaluate loss/accuracy over a batch list (round-robin sharding).
    /// Returns (mean loss, accuracy, samples).
    pub fn evaluate(
        &mut self,
        base: &[f32],
        lora: Option<(&[f32], &[f32])>,
        batches: Vec<Batch>,
    ) -> Result<(f64, f64, usize)> {
        ensure!(!batches.is_empty(), "no eval batches");
        let bsz = self.manifest.config.batch_size;
        let n_batches = batches.len();
        let mut loss = 0.0;
        let mut correct = 0.0;
        // dispatch in waves of worker-count
        let mut batches = batches;
        while !batches.is_empty() {
            let take = batches.len().min(self.n_workers.max(1));
            let wave: Vec<Batch> = batches.drain(..take).collect();
            let outs = self.eval_dispatch(lora.is_some(), base, lora, wave)?;
            for o in outs {
                loss += o.loss as f64;
                correct += o.correct as f64;
            }
        }
        let samples = n_batches * bsz;
        Ok((loss / n_batches as f64, correct / samples as f64, samples))
    }

    /// Fan one evaluation wave out to the workers (training steps go
    /// through [`submit`](Self::submit)/[`collect`](Self::collect)).
    fn eval_dispatch(
        &mut self,
        eval_lora: bool,
        base: &[f32],
        lora: Option<(&[f32], &[f32])>,
        batches: Vec<Batch>,
    ) -> Result<Vec<WorkerOut>> {
        ensure!(self.in_flight == 0, "cannot evaluate with a step in flight");
        if self.threaded {
            self.fan_out(None, eval_lora, base, lora, batches)?;
            self.recv_all()
        } else {
            // sequential path: zero-copy borrows straight into the runtime
            let rt = self
                .local
                .as_mut()
                .ok_or_else(|| anyhow!("sequential engine has no local runtime"))?;
            let mut outs = Vec::with_capacity(batches.len());
            for (w, batch) in batches.iter().enumerate() {
                let mut o = run_job(rt, &self.manifest, None, eval_lora, base, lora, batch)?;
                o.worker = w;
                outs.push(o);
            }
            Ok(outs)
        }
    }
}

impl Drop for GradEngine {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, EpochLoader, SynthSpec};
    use std::path::PathBuf;

    fn micro() -> Arc<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-micro");
        Arc::new(Manifest::load(dir).expect("run `make artifacts` first"))
    }

    fn data(m: &Manifest, samples: usize) -> Dataset {
        let c = &m.config;
        Dataset::generate(&SynthSpec {
            samples,
            image_size: c.image_size,
            channels: c.in_channels,
            num_classes: c.num_classes,
            noise: 0.3,
            phase_jitter: true,
            seed: 11,
        })
    }

    #[test]
    fn sequential_full_step_produces_grads() {
        let m = micro();
        let d = data(&m, 64);
        let loader = EpochLoader::new(m.config.batch_size, 1, 0);
        let mut eng = GradEngine::new(m.clone(), 1, false, Algorithm::Naive).unwrap();
        let base = m.load_init_base().unwrap();
        let batches = loader.step_batches(&d, 0, 0);
        let r = eng.compute(StepMode::Full, &base, None, batches).unwrap();
        let g = r.d_base.unwrap().into_full();
        assert_eq!(g.len(), m.base.size);
        assert!(crate::tensor::l2_norm(&g) > 0.0);
        assert!(r.loss.is_finite() && r.loss > 0.0);
        assert!(r.d_lora.is_none());
    }

    #[test]
    fn threaded_matches_sequential_bitwise() {
        // The DP equivalence invariant: threading must not change numerics
        // (deterministic shard order + ordered reduction).
        let m = micro();
        let d = data(&m, 64);
        let workers = 2;
        let loader = EpochLoader::new(m.config.batch_size, workers, 0);
        let base = m.load_init_base().unwrap();
        let batches = loader.step_batches(&d, 0, 0);

        let mut seq = GradEngine::new(m.clone(), workers, false, Algorithm::Tree).unwrap();
        let r1 = seq.compute(StepMode::Full, &base, None, batches.clone()).unwrap();
        let mut thr = GradEngine::new(m.clone(), workers, true, Algorithm::Tree).unwrap();
        let r2 = thr.compute(StepMode::Full, &base, None, batches).unwrap();

        assert_eq!(r1.d_base.as_ref().unwrap(), r2.d_base.as_ref().unwrap());
        assert_eq!(r1.loss, r2.loss);
        assert_eq!(r1.correct, r2.correct);
    }

    #[test]
    fn split_submit_collect_matches_compute() {
        // the pipeline's submit/collect path must see exactly what the
        // one-shot compute path sees
        let m = micro();
        let d = data(&m, 64);
        let workers = 2;
        let loader = EpochLoader::new(m.config.batch_size, workers, 0);
        let base = m.load_init_base().unwrap();
        let batches = loader.step_batches(&d, 0, 0);
        let mut eng = GradEngine::new(m.clone(), workers, false, Algorithm::Tree).unwrap();
        let r1 = eng.compute(StepMode::Full, &base, None, batches.clone()).unwrap();
        eng.submit(StepMode::Full, &base, None, batches.clone()).unwrap();
        // a second submit with a step in flight must be rejected
        assert!(eng.submit(StepMode::Full, &base, None, batches).is_err());
        let outs = eng.collect().unwrap();
        assert_eq!(outs.base_grads.len(), workers);
        assert!(outs.lora_grads.is_empty());
        let r2 = outs.reduce(Algorithm::Tree);
        assert_eq!(r1.d_base, r2.d_base);
        assert_eq!(r1.loss, r2.loss);
        assert_eq!(r1.correct, r2.correct);
        assert_eq!(r1.samples, r2.samples);
        // collect with nothing in flight must be rejected; drain is a no-op
        assert!(eng.collect().is_err());
        eng.drain();
    }

    #[test]
    fn bucket_route_publishes_slices_that_reduce_bitwise() {
        // with a route installed, collect() sees scalars only; the bucket
        // queue carries every worker's slices, and reassembling + reducing
        // them whole-buffer reproduces the unrouted gradient exactly
        let m = micro();
        let d = data(&m, 64);
        let workers = 2;
        let loader = EpochLoader::new(m.config.batch_size, workers, 0);
        let base = m.load_init_base().unwrap();
        let batches = loader.step_batches(&d, 0, 0);
        let mut eng = GradEngine::new(m.clone(), workers, false, Algorithm::Tree).unwrap();
        let r1 = eng.compute(StepMode::Full, &base, None, batches.clone()).unwrap();
        let want = r1.d_base.unwrap().into_full();

        let plan = Arc::new(BucketPlan::derive(m.base.size, 1, 1024));
        // capacity covers every message: this test drains only afterwards
        let (tx, rx) = BucketTx::channel(plan.count() * workers + 1);
        eng.set_bucket_route(Some(BucketRoute { base: Some(plan.clone()), lora: None, tx }));
        eng.submit(StepMode::Full, &base, None, batches).unwrap();
        let outs = eng.collect().unwrap();
        assert!(outs.base_grads.is_empty(), "published buffers must not reach collect");
        assert!(outs.lora_grads.is_empty());
        assert!(outs.loss.is_finite());
        eng.set_bucket_route(None);

        let mut per_worker = vec![vec![0.0f32; m.base.size]; workers];
        let mut got = 0usize;
        for ctrl in rx.try_iter() {
            let BucketCtrl::Bucket(msg) = ctrl else {
                panic!("workers publish buckets only, never lifecycle signals");
            };
            assert_eq!(msg.space, GradSpace::Base);
            assert_eq!(msg.full_len, m.base.size);
            let b = plan.buckets[msg.bucket];
            assert_eq!(msg.lo, b.lo);
            per_worker[msg.worker][b.lo..b.hi].copy_from_slice(&msg.data);
            got += 1;
        }
        assert_eq!(got, plan.count() * workers);
        let r2 = reduce_owned(Algorithm::Tree, per_worker).unwrap();
        assert_eq!(r2, want, "bucketed slices must reduce bitwise to the whole buffer");
    }

    #[test]
    fn injected_faults_fire_on_the_armed_step_only() {
        use crate::faults::ComputeFaultKind;
        let m = micro();
        let d = data(&m, 64);
        let workers = 2;
        let loader = EpochLoader::new(m.config.batch_size, workers, 0);
        let base = m.load_init_base().unwrap();
        let batches = loader.step_batches(&d, 0, 0);
        let mut eng = GradEngine::new(m.clone(), workers, false, Algorithm::Tree).unwrap();
        let clean = eng.compute(StepMode::Full, &base, None, batches.clone()).unwrap();

        // a straggler sleeps but must not change a bit of the step
        eng.set_step_faults(vec![Some(ComputeFault {
            kind: ComputeFaultKind::Straggle { ms: 5 },
            epoch: 0,
            step: 0,
        })]);
        let slow = eng.compute(StepMode::Full, &base, None, batches.clone()).unwrap();
        assert_eq!(clean.d_base, slow.d_base, "straggler changed the gradients");
        assert_eq!(clean.loss, slow.loss);

        // an abort is a loud contextful error naming the coordinate
        eng.set_step_faults(vec![
            None,
            Some(ComputeFault { kind: ComputeFaultKind::Abort, epoch: 3, step: 1 }),
        ]);
        let err =
            format!("{:#}", eng.compute(StepMode::Full, &base, None, batches.clone()).unwrap_err());
        assert!(err.contains("fault injected"), "{err}");
        assert!(err.contains("epoch 3, step 1"), "{err}");
        eng.drain();

        // a panic fault surfaces as the worker-panicked error, not a crash
        eng.set_step_faults(vec![Some(ComputeFault {
            kind: ComputeFaultKind::Panic,
            epoch: 0,
            step: 0,
        })]);
        let err =
            format!("{:#}", eng.compute(StepMode::Full, &base, None, batches.clone()).unwrap_err());
        assert!(err.contains("worker 0 panicked"), "{err}");
        assert!(err.contains("fault injected"), "{err}");
        eng.drain();

        // the armed faults are consumed: the next step runs clean
        let again = eng.compute(StepMode::Full, &base, None, batches).unwrap();
        assert_eq!(clean.d_base, again.d_base);
    }

    #[test]
    fn lora_step_leaves_base_gradient_absent() {
        let m = micro();
        let d = data(&m, 32);
        let loader = EpochLoader::new(m.config.batch_size, 1, 0);
        let mut eng = GradEngine::new(m.clone(), 1, false, Algorithm::Naive).unwrap();
        let mut base = m.load_init_base().unwrap();
        // the zero-init head makes every trunk gradient vanish at init
        // (d pooled = head.w @ d logits = 0); randomize it as real training
        // would have by the time the switch happens
        let mut rng = crate::tensor::Pcg64::new(3);
        for t in &m.base.tensors {
            if t.module == "head" && t.is_weight_matrix() {
                rng.fill_normal(&mut base[t.offset..t.offset + t.size], 0.05);
            }
        }
        // uniform rank-2 adapters, A random / B zero
        let mut lora = vec![0.0f32; m.lora.size];
        for t in &m.lora.tensors {
            if t.module == "lora_a" {
                rng.fill_normal(&mut lora[t.offset..t.offset + t.size], 0.02);
            }
        }
        let modules: Vec<String> =
            crate::manifest::ADAPTED_MODULES.iter().map(|s| s.to_string()).collect();
        let assign = crate::rank::uniform_ranks(&modules, m.config.depth, 2);
        let acfg = crate::rank::build_adapter_cfg(&m, &assign, m.config.lora_alpha).unwrap();
        let batches = loader.step_batches(&d, 0, 0);
        let r = eng
            .compute(StepMode::LoraOnly, &base, Some((&lora, &acfg.values)), batches)
            .unwrap();
        assert!(r.d_base.is_none());
        let dl = r.d_lora.unwrap().into_full();
        assert_eq!(dl.len(), m.lora.size);
        assert!(crate::tensor::l2_norm(&dl) > 0.0);
    }

    #[test]
    fn evaluate_returns_chance_accuracy_at_init() {
        let m = micro();
        let d = data(&m, 64);
        let loader = EpochLoader::new(m.config.batch_size, 1, 0);
        let mut eng = GradEngine::new(m.clone(), 1, false, Algorithm::Naive).unwrap();
        let base = m.load_init_base().unwrap();
        let (loss, acc, samples) = eng.evaluate(&base, None, loader.eval_batches(&d)).unwrap();
        assert_eq!(samples, 64);
        // zero head => exactly ln(K) loss, accuracy near chance
        assert!((loss - (m.config.num_classes as f64).ln()).abs() < 0.05);
        assert!(acc <= 0.5);
    }
}
