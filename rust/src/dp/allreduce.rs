//! Gradient all-reduce algorithms.
//!
//! All three compute the elementwise *mean* of N same-length gradient
//! buffers into the first buffer. They are numerically different summation
//! orders of the same reduction:
//!
//! * `Naive` — leader sums sequentially; O(N * n) work on one core, the
//!   baseline a single-process DDP leader would do.
//! * `Tree`  — pairwise reduction, log2(N) rounds; pairs are summed in
//!   parallel with scoped threads (the NCCL tree pattern).
//! * `Ring`  — chunked reduce-scatter + all-gather schedule (the NCCL ring
//!   pattern). In-memory the data movement is simulated by the chunk
//!   schedule; the arithmetic matches a real ring exactly.

use std::str::FromStr;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Naive,
    Tree,
    Ring,
}

impl Algorithm {
    /// Canonical config-file spelling (round-trips through [`FromStr`]).
    pub fn as_str(self) -> &'static str {
        match self {
            Algorithm::Naive => "naive",
            Algorithm::Tree => "tree",
            Algorithm::Ring => "ring",
        }
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for Algorithm {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(Algorithm::Naive),
            "tree" => Ok(Algorithm::Tree),
            "ring" => Ok(Algorithm::Ring),
            other => Err(format!(
                "unknown allreduce algorithm {other:?} (expected {}, {} or {})",
                Algorithm::Naive,
                Algorithm::Tree,
                Algorithm::Ring
            )),
        }
    }
}

/// A reduced gradient buffer in one of its two distributed layouts.
///
/// `Full` is the classic DDP picture: every worker holds the whole mean
/// vector. `Sharded` is the ZeRO-2 picture: worker `w` owns partition `w`
/// of the same vector (the [`partition`] chunking) and nothing else —
/// the non-owned chunks are freed at the reduce, so per-rank gradient
/// memory is ~1/parts of the buffer. The concatenation of the shards is
/// **bitwise** the `Full` vector — both layouts run the same summation
/// schedule, so which one a run uses cannot change losses.
#[derive(Debug, Clone, PartialEq)]
pub enum Reduced {
    Full(Vec<f32>),
    /// One owned chunk per partition, in partition order; chunks may be
    /// empty when there are more partitions than elements.
    Sharded(Vec<Vec<f32>>),
}

impl Reduced {
    /// Total element count across the layout.
    pub fn len(&self) -> usize {
        match self {
            Reduced::Full(v) => v.len(),
            Reduced::Sharded(chunks) => chunks.iter().map(Vec::len).sum(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the full vector (all-gather for the sharded layout).
    pub fn into_full(self) -> Vec<f32> {
        match self {
            Reduced::Full(v) => v,
            Reduced::Sharded(chunks) => all_gather(&chunks),
        }
    }

    /// Elements a single rank retains in this layout: the whole buffer
    /// when replicated, the largest owned partition when sharded (the
    /// quantity behind `MemoryBreakdown.grad_bytes` under ZeRO-2).
    pub fn per_rank_elems(&self) -> usize {
        match self {
            Reduced::Full(v) => v.len(),
            Reduced::Sharded(chunks) => chunks.iter().map(Vec::len).max().unwrap_or(0),
        }
    }
}

/// Contiguous `(lo, hi)` partition bounds of a length-`len` vector over
/// `parts` owners — the ring algorithm's chunking (`ceil(len / parts)`
/// sized chunks, a possibly ragged final chunk, empty chunks when
/// `parts > len`). This is the one chunking used by [`reduce_scatter`],
/// the ZeRO optimizer sharding and the checkpoint gather, so shard layouts
/// agree everywhere by construction.
pub fn partition(len: usize, parts: usize) -> Vec<(usize, usize)> {
    assert!(parts >= 1, "need at least one partition");
    let chunk = len.div_ceil(parts);
    (0..parts)
        .map(|c| ((c * chunk).min(len), ((c + 1) * chunk).min(len)))
        .collect()
}

/// One bucket of a gradient space: the element range `[lo, hi)` plus the
/// index of the [`partition`] chunk that wholly contains it. Buckets never
/// straddle a partition boundary, so under ZeRO sharding every bucket has
/// exactly one owning rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bucket {
    pub lo: usize,
    pub hi: usize,
    /// Index of the grad partition this bucket lies inside.
    pub part: usize,
}

impl Bucket {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.lo >= self.hi
    }
}

/// The bucket layout of one gradient space: contiguous, size-bounded
/// sub-ranges of `[0, len)` whose boundaries include every grad-partition
/// boundary (each bucket lies fully inside one [`partition`] chunk, so
/// ZeRO-1/2/3 ownership is bucket-local). Derived per space length —
/// callers re-derive whenever a `Repartition` event changes which spaces
/// are live or how long they are.
///
/// `bucket_bytes = 0` means "whole-buffer": one bucket per non-empty
/// partition, i.e. exactly the unbucketed reduce-scatter layout (and a
/// single whole-space bucket when `parts == 1`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketPlan {
    /// Full length of the gradient space the plan covers.
    pub len: usize,
    /// Grad partition count the boundaries are aligned to.
    pub parts: usize,
    /// Buckets in ascending index order (ascending `lo`, covering
    /// `[0, len)` contiguously; empty for a zero-length space).
    pub buckets: Vec<Bucket>,
}

impl BucketPlan {
    /// Derive the layout: split each of the `parts` grad partitions of a
    /// length-`len` space into pieces of at most `max(1, bucket_bytes/4)`
    /// f32 elements. Degenerate sizes are safe by construction — a bucket
    /// size below one element clamps to single-element buckets, and one
    /// larger than the space (or 0) degrades to whole-partition buckets.
    pub fn derive(len: usize, parts: usize, bucket_bytes: usize) -> Self {
        let parts = parts.max(1);
        let max_elems = if bucket_bytes == 0 {
            len.max(1)
        } else {
            (bucket_bytes / 4).max(1)
        };
        let mut buckets = Vec::new();
        for (part, (plo, phi)) in partition(len, parts).into_iter().enumerate() {
            let mut lo = plo;
            while lo < phi {
                let hi = (lo + max_elems).min(phi);
                buckets.push(Bucket { lo, hi, part });
                lo = hi;
            }
        }
        Self { len, parts, buckets }
    }

    pub fn count(&self) -> usize {
        self.buckets.len()
    }
}

/// Reduce one *bucket* of the gradient space: `bufs[w]` is worker `w`'s
/// elements `[lo, lo + bufs[w].len())` of its full length-`full_len`
/// buffer, and the result is the elementwise mean of that slice.
///
/// **Bit contract:** the returned slice equals `reduce_owned(alg,
/// full_bufs)[lo..hi]` exactly — per element the identical additions in
/// the identical order, only restricted to the bucket's range:
///
/// * `Naive`/`Tree` schedules are position-independent (the same
///   worker-order / pairwise folds per element), so they run on the
///   bucket-local slices directly.
/// * `Ring`'s summation order depends on which *global* ring chunk an
///   element falls in, so the fold is replayed per overlapped chunk of
///   `partition(full_len, n)`: chunk `c`'s elements accumulate as
///   `acc = bufs[c]`, then `acc = bufs[(c+k) % n] + acc` for
///   `k = 1..n` — the exact `dst += src` chain [`ring_rounds`] performs.
///
/// A single worker is the identity (no scaling), matching `reduce_mean`'s
/// early return. Returns `None` for an empty worker set.
pub fn reduce_bucket(
    alg: Algorithm,
    mut bufs: Vec<Vec<f32>>,
    lo: usize,
    full_len: usize,
) -> Option<Vec<f32>> {
    let n = bufs.len();
    if n == 0 {
        return None;
    }
    let blen = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == blen), "bucket slice length mismatch");
    assert!(
        lo + blen <= full_len,
        "bucket [{lo}, {}) exceeds the space length {full_len}",
        lo + blen
    );
    if n == 1 {
        return Some(bufs.swap_remove(0));
    }
    let mut out = match alg {
        Algorithm::Naive => naive_range(&bufs, 0, blen),
        Algorithm::Tree => tree_range(&bufs, 0, blen),
        Algorithm::Ring => {
            let hi = lo + blen;
            let mut out = Vec::with_capacity(blen);
            for (c, &(rlo, rhi)) in partition(full_len, n).iter().enumerate() {
                let (s, e) = (lo.max(rlo), hi.min(rhi));
                if s >= e {
                    continue;
                }
                let (bs, be) = (s - lo, e - lo);
                let mut acc = bufs[c][bs..be].to_vec();
                for k in 1..n {
                    let src = &bufs[(c + k) % n][bs..be];
                    for (a, &v) in acc.iter_mut().zip(src) {
                        // operand order matches the ring's dst += src:
                        // the receiving rank's value on the left, the
                        // accumulated chunk on the right
                        *a = v + *a;
                    }
                }
                out.extend_from_slice(&acc);
            }
            debug_assert_eq!(out.len(), blen);
            out
        }
    };
    let inv = 1.0 / n as f32;
    for v in out.iter_mut() {
        *v *= inv;
    }
    Some(out)
}

/// Reduce `bufs` to their elementwise mean, left in `bufs[0]`.
/// Returns early on a single buffer. Panics on length mismatch.
pub fn reduce_mean(alg: Algorithm, bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "buffer length mismatch");
    match alg {
        Algorithm::Naive => naive(bufs),
        Algorithm::Tree => tree(bufs),
        Algorithm::Ring => ring(bufs),
    }
    let inv = 1.0 / n as f32;
    for v in bufs[0].iter_mut() {
        *v *= inv;
    }
}

/// Reduce-scatter: the elementwise mean of `bufs`, returned as `parts`
/// owned chunks ([`partition`] layout) instead of one replicated vector.
/// This is the **terminal** op on the ZeRO-2 hot path: no full-length
/// mean-gradient buffer is materialized afterward, and the per-worker
/// input buffers are consumed (dropped) here — what survives the reduce
/// is exactly one owned chunk per partition.
///
/// **Bit contract:** concatenating the returned chunks yields exactly the
/// vector [`reduce_owned`] would have produced for the same `alg` — the
/// per-element summation order is identical, only the final placement
/// differs.
///
/// * `Ring` runs its reduce-scatter rounds and then assembles each owned
///   output chunk straight from the ranks the schedule completed it on —
///   with `parts == bufs.len()` each output chunk *is* one ring chunk
///   (the real ZeRO traffic saving: the gather phase is skipped
///   entirely), and a foreign `parts` count just stitches each output
///   chunk from the ring chunks it overlaps. Either way no full-length
///   reduced vector is ever materialized.
/// * `Naive` and `Tree` run their schedule *per owned chunk* — the
///   sequential leader sum and the pairwise stride-doubling rounds
///   restricted to the chunk's element range — so the largest live
///   temporary is one chunk, never a full-length reduced vector.
pub fn reduce_scatter(
    alg: Algorithm,
    mut bufs: Vec<Vec<f32>>,
    parts: usize,
) -> Option<Vec<Vec<f32>>> {
    let n = bufs.len();
    if n == 0 {
        return None;
    }
    let len = bufs[0].len();
    if n == 1 {
        let full = bufs.swap_remove(0);
        return Some(scatter(&full, parts));
    }
    assert!(bufs.iter().all(|b| b.len() == len), "buffer length mismatch");
    let inv = 1.0 / n as f32;
    if alg == Algorithm::Ring {
        // the ring's summation schedule is tied to the worker count, not
        // the output partition: run the rounds over the ring's own
        // chunking, then assemble each output chunk from the rank(s)
        // holding the completed ring chunks it overlaps. The additions
        // are exactly the full all-reduce's, so the concatenation of the
        // output chunks is bitwise the all-reduce result for *any*
        // `parts` (this used to reduce fully then split when
        // `parts != n` — same bits, but it materialized the full vector).
        ring_rounds(&mut bufs);
        let ring_bounds = partition(len, n);
        let out = partition(len, parts)
            .into_iter()
            .map(|(lo, hi)| {
                let mut chunk = Vec::with_capacity(hi - lo);
                for (c, &(rlo, rhi)) in ring_bounds.iter().enumerate() {
                    let (s, e) = (lo.max(rlo), hi.min(rhi));
                    if s < e {
                        // rank (c-1) mod n holds the fully-summed chunk c
                        chunk.extend_from_slice(&bufs[(c + n - 1) % n][s..e]);
                    }
                }
                debug_assert_eq!(chunk.len(), hi - lo);
                for v in chunk.iter_mut() {
                    *v *= inv;
                }
                chunk
            })
            .collect();
        return Some(out);
    }
    let reduce_range: fn(&[Vec<f32>], usize, usize) -> Vec<f32> = match alg {
        Algorithm::Naive => naive_range,
        Algorithm::Tree => tree_range,
        Algorithm::Ring => unreachable!("handled above"),
    };
    let out = partition(len, parts)
        .into_iter()
        .map(|(lo, hi)| {
            let mut chunk = reduce_range(&bufs, lo, hi);
            for v in chunk.iter_mut() {
                *v *= inv;
            }
            chunk
        })
        .collect();
    Some(out)
}

/// The naive schedule restricted to one chunk: the leader's sequential
/// worker-order sum over `bufs[..][lo..hi]`. Per element this performs
/// the identical additions as [`naive`], so the result is bitwise the
/// full naive reduce's slice.
fn naive_range(bufs: &[Vec<f32>], lo: usize, hi: usize) -> Vec<f32> {
    let mut acc = bufs[0][lo..hi].to_vec();
    for b in &bufs[1..] {
        crate::tensor::add_assign(&mut acc, &b[lo..hi]);
    }
    acc
}

/// The tree schedule restricted to one chunk: pairwise stride-doubling
/// rounds over `bufs[..][lo..hi]`. The pairs are exactly [`tree`]'s
/// (dst `base`, src `base + stride`), so per element the balanced-tree
/// additions are identical and the result is bitwise the full tree
/// reduce's slice; running the disjoint pairs sequentially instead of on
/// scoped threads cannot change the bits.
fn tree_range(bufs: &[Vec<f32>], lo: usize, hi: usize) -> Vec<f32> {
    let n = bufs.len();
    let mut chunks: Vec<Vec<f32>> = bufs.iter().map(|b| b[lo..hi].to_vec()).collect();
    let mut stride = 1;
    while stride < n {
        let step = stride * 2;
        let mut base = 0;
        while base + stride < n {
            let (head, tail) = chunks.split_at_mut(base + stride);
            crate::tensor::add_assign(&mut head[base], &tail[0]);
            base += step;
        }
        stride = step;
    }
    chunks.swap_remove(0)
}

/// Ordered scalar reduction for the ZeRO-2 global gradient norm: fold the
/// squared elements of [`partition`]-ordered chunks into one f64 sum, in
/// chunk-then-element order. This is **bitwise** the accumulation
/// [`sq_norm`] performs over the concatenated full buffer (an f64 left
/// fold over a concatenation equals the fold over the chunks carried in
/// order), which is what keeps sharded clipping — and therefore sharded
/// training — bit-identical to the full-buffer path. A real cluster
/// would all-reduce independent per-shard partial sums, which is cheaper
/// but regroups the f64 additions (not associative); we deliberately keep
/// the chained order so turning ZeRO on can never change losses.
///
/// [`sq_norm`]: crate::tensor::sq_norm
pub fn sq_sum_in_order(chunks: &[Vec<f32>]) -> f64 {
    let mut acc = 0.0f64;
    for c in chunks {
        for &x in c {
            acc += (x as f64) * (x as f64);
        }
    }
    acc
}

/// Split a full vector into owned [`partition`] chunks (copies).
pub fn scatter(full: &[f32], parts: usize) -> Vec<Vec<f32>> {
    partition(full.len(), parts)
        .into_iter()
        .map(|(lo, hi)| full[lo..hi].to_vec())
        .collect()
}

/// All-gather: reassemble the full vector from [`partition`]-ordered
/// chunks — the inverse of [`scatter`], and the step that rebuilds the
/// replicated parameter vector after each ZeRO shard update.
pub fn all_gather(chunks: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::with_capacity(chunks.iter().map(Vec::len).sum());
    for c in chunks {
        out.extend_from_slice(c);
    }
    out
}

/// Owned-buffer variant: reduce to the mean and hand back the first
/// buffer, or `None` for an empty set. The primitive shared by
/// `GradEngine::compute` and the pipeline's [`ReduceStage`] — both paths
/// reduce through this exact summation schedule, which is what makes the
/// pipelined loop bit-identical to the serial one.
///
/// [`ReduceStage`]: crate::pipeline::ReduceStage
pub fn reduce_owned(alg: Algorithm, mut bufs: Vec<Vec<f32>>) -> Option<Vec<f32>> {
    if bufs.is_empty() {
        return None;
    }
    reduce_mean(alg, &mut bufs);
    Some(bufs.swap_remove(0))
}

fn naive(bufs: &mut [Vec<f32>]) {
    let (first, rest) = bufs.split_at_mut(1);
    for b in rest.iter() {
        crate::tensor::add_assign(&mut first[0], b);
    }
}

fn tree(bufs: &mut [Vec<f32>]) {
    // pairwise rounds: stride doubles each round; each pair sums in parallel
    let n = bufs.len();
    let mut stride = 1;
    while stride < n {
        let step = stride * 2;
        // split bufs into disjoint (dst, src) pairs for this round
        std::thread::scope(|scope| {
            let mut rest = &mut bufs[..];
            let mut base = 0;
            while base + stride < n {
                let take = (step).min(rest.len());
                let (chunk, tail) = rest.split_at_mut(take);
                rest = tail;
                let (dst, src) = chunk.split_at_mut(stride);
                scope.spawn(move || {
                    crate::tensor::add_assign(&mut dst[0], &src[0]);
                });
                base += step;
            }
        });
        stride = step;
    }
}

fn ring(bufs: &mut [Vec<f32>]) {
    // reduce-scatter rounds, then gather the owned chunks into rank 0 (we
    // only need the full sum there) — the chunk schedule (which rank sums
    // what, when) matches a textbook ring exactly.
    ring_rounds(bufs);
    let n = bufs.len();
    let bounds = partition(bufs[0].len(), n);
    // gather: rank (c-1) mod n owns the fully-reduced chunk c
    for c in 0..n {
        let owner = (c + n - 1) % n;
        if owner == 0 {
            continue;
        }
        let (lo, hi) = bounds[c];
        if lo >= hi {
            continue;
        }
        let (head, tail) = bufs.split_at_mut(1);
        head[0][lo..hi].copy_from_slice(&tail[owner - 1][lo..hi]);
    }
}

/// The ring's reduce-scatter phase: rank i receives chunk (i - round - 1)
/// mod N from its left neighbor each round, so after N-1 rounds rank i
/// holds the fully summed chunk (i + 1) mod N — equivalently, chunk c
/// completes on rank (c - 1) mod N. Shared by the full all-reduce and
/// [`reduce_scatter`], which is what keeps their summation orders (and
/// therefore bits) identical.
fn ring_rounds(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    let bounds = partition(bufs[0].len(), n);
    for round in 0..n - 1 {
        for rank in 0..n {
            // rank receives chunk (rank - round - 1) from its left neighbor
            let c = (rank + n - round - 1) % n;
            let src = (rank + n - 1) % n;
            let (lo, hi) = bounds[c];
            if lo >= hi {
                continue;
            }
            // sum src's chunk into rank's chunk
            let (a, b) = if src < rank {
                let (l, r) = bufs.split_at_mut(rank);
                (&l[src], &mut r[0])
            } else {
                let (l, r) = bufs.split_at_mut(src);
                (&r[0], &mut l[rank])
            };
            // note: direction matters — data flows src -> rank
            let (src_buf, dst_buf) = (a, b);
            for i in lo..hi {
                dst_buf[i] += src_buf[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_bufs(n: usize, len: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|w| (0..len).map(|i| ((w * 31 + i * 7) % 13) as f32 - 6.0).collect())
            .collect();
        let mut want = vec![0.0f32; len];
        for b in &bufs {
            for (o, v) in want.iter_mut().zip(b) {
                *o += v;
            }
        }
        for v in want.iter_mut() {
            *v /= n as f32;
        }
        (bufs, want)
    }

    fn check(alg: Algorithm, n: usize, len: usize) {
        let (mut bufs, want) = make_bufs(n, len);
        reduce_mean(alg, &mut bufs);
        for (i, (&got, &want)) in bufs[0].iter().zip(&want).enumerate() {
            assert!((got - want).abs() < 1e-4, "{alg:?} n={n} len={len} idx={i}: {got} vs {want}");
        }
    }

    #[test]
    fn all_algorithms_agree_with_mean() {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            for n in [2usize, 3, 4, 7, 8, 16] {
                for len in [1usize, 5, 64, 1000] {
                    check(alg, n, len);
                }
            }
        }
    }

    #[test]
    fn single_buffer_is_identity() {
        let mut bufs = vec![vec![1.0, 2.0, 3.0]];
        reduce_mean(Algorithm::Ring, &mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn odd_worker_counts_and_unaligned_lengths_agree() {
        // the ring schedule's chunking is the interesting case: worker
        // counts that don't divide the buffer length exercise the ragged
        // final chunk and the empty-chunk guard
        for n in [3usize, 5, 7] {
            for len in [1usize, 2, 17, 33, 101, 1023] {
                check(Algorithm::Naive, n, len);
                check(Algorithm::Tree, n, len);
                check(Algorithm::Ring, n, len);
            }
        }
    }

    #[test]
    fn parse_algorithm() {
        assert_eq!("ring".parse::<Algorithm>().unwrap(), Algorithm::Ring);
        assert_eq!("tree".parse::<Algorithm>().unwrap(), Algorithm::Tree);
        assert!("mesh".parse::<Algorithm>().is_err());
    }

    #[test]
    fn display_roundtrips_case_insensitively() {
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            assert_eq!(alg.to_string().parse::<Algorithm>().unwrap(), alg);
            assert_eq!(
                alg.to_string().to_uppercase().parse::<Algorithm>().unwrap(),
                alg
            );
        }
        let err = "mesh".parse::<Algorithm>().unwrap_err();
        assert!(err.contains("naive") && err.contains("ring"), "{err}");
    }

    #[test]
    fn reduce_owned_returns_first_buffer_mean() {
        let (bufs, want) = make_bufs(3, 10);
        let got = reduce_owned(Algorithm::Tree, bufs).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
        assert!(reduce_owned(Algorithm::Tree, Vec::new()).is_none());
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let mut bufs = vec![vec![1.0; 4], vec![1.0; 5]];
        reduce_mean(Algorithm::Naive, &mut bufs);
    }

    #[test]
    fn partition_covers_contiguously() {
        for (len, parts) in [(10usize, 3usize), (7, 7), (3, 8), (0, 2), (1023, 5), (16, 1)] {
            let b = partition(len, parts);
            assert_eq!(b.len(), parts);
            let mut at = 0;
            for &(lo, hi) in &b {
                assert_eq!(lo, at);
                assert!(hi >= lo && hi <= len);
                at = hi;
            }
            assert_eq!(at, len, "partition must cover [0, {len})");
        }
    }

    #[test]
    fn reduce_scatter_concat_is_bitwise_reduce_owned() {
        // the ZeRO bit contract: per algorithm, per ragged shape, the
        // scattered chunks concatenate to *exactly* the all-reduce output
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            for n in [1usize, 2, 3, 5, 7, 8] {
                for len in [1usize, 2, 17, 64, 101] {
                    let (bufs, _) = make_bufs(n, len);
                    let want = reduce_owned(alg, bufs.clone()).unwrap();
                    let chunks = reduce_scatter(alg, bufs, n).unwrap();
                    assert_eq!(chunks.len(), n);
                    let got = all_gather(&chunks);
                    assert_eq!(got, want, "{alg:?} n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_part_count_independent_of_workers() {
        // shard layout (parts) need not match the reducing worker count
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            let (bufs, _) = make_bufs(4, 33);
            let want = reduce_owned(alg, bufs.clone()).unwrap();
            for parts in [1usize, 2, 3, 7, 40] {
                let chunks = reduce_scatter(alg, bufs.clone(), parts).unwrap();
                assert_eq!(chunks.len(), parts);
                assert_eq!(all_gather(&chunks), want, "{alg:?} parts={parts}");
            }
        }
    }

    #[test]
    fn scattered_schedules_match_full_reduce_bitwise() {
        // the genuinely-scattered per-chunk schedules (no full-length
        // temporary) must reproduce the full reduce bit-for-bit, including
        // odd worker counts and ragged/empty chunks. Ring included: its
        // foreign-`parts` path used to reduce fully then split, and now
        // stitches output chunks from the ring chunks' owning ranks.
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            for n in [2usize, 3, 5, 7, 8, 16] {
                for len in [1usize, 2, 17, 101, 1023] {
                    for parts in [1usize, 2, 3, n, 2 * n, len + 3] {
                        let (bufs, _) = make_bufs(n, len);
                        let want = reduce_owned(alg, bufs.clone()).unwrap();
                        let chunks = reduce_scatter(alg, bufs, parts).unwrap();
                        assert_eq!(
                            all_gather(&chunks),
                            want,
                            "{alg:?} n={n} len={len} parts={parts}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sq_sum_in_order_is_bitwise_the_full_fold() {
        // ragged 3-way and 5-way splits of an awkward length: the chained
        // chunk fold must equal tensor::sq_norm on the concatenation
        let full: Vec<f32> = (0..103).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.37).collect();
        for parts in [1usize, 2, 3, 5, 103, 200] {
            let chunks = scatter(&full, parts);
            assert_eq!(
                sq_sum_in_order(&chunks),
                crate::tensor::sq_norm(&full),
                "parts={parts}"
            );
        }
        assert_eq!(sq_sum_in_order(&[]), 0.0);
    }

    #[test]
    fn per_rank_elems_reports_largest_owned_chunk() {
        let full = vec![0.5f32; 10];
        assert_eq!(Reduced::Full(full.clone()).per_rank_elems(), 10);
        // 10 over 4 parts: chunks of 3,3,3,1 -> largest is 3
        assert_eq!(Reduced::Sharded(scatter(&full, 4)).per_rank_elems(), 3);
        assert_eq!(Reduced::Sharded(Vec::new()).per_rank_elems(), 0);
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let full: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 9.0).collect();
        for parts in [1usize, 2, 5, 37, 50] {
            let chunks = scatter(&full, parts);
            assert_eq!(chunks.len(), parts);
            assert_eq!(all_gather(&chunks), full);
        }
        assert!(reduce_scatter(Algorithm::Tree, Vec::new(), 3).is_none());
    }

    #[test]
    fn reduced_layouts_agree_on_len_and_full() {
        let full = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let sharded = Reduced::Sharded(scatter(&full, 3));
        assert_eq!(sharded.len(), 5);
        assert!(!sharded.is_empty());
        assert_eq!(sharded.into_full(), full);
        assert_eq!(Reduced::Full(full.clone()).len(), 5);
        assert_eq!(Reduced::Full(full.clone()).into_full(), full);
    }

    #[test]
    fn bucket_plan_aligns_to_partitions_and_bounds_size() {
        // degenerate sizes included: smaller than one partition (many
        // buckets per partition), larger than the whole space (one bucket
        // per partition), zero (whole-buffer), parts > len (empty
        // partitions contribute no buckets)
        for (len, parts, bytes) in [
            (101usize, 3usize, 16usize),
            (101, 3, 4096),
            (101, 3, 0),
            (101, 1, 40),
            (7, 7, 4),
            (3, 8, 8),
            (0, 2, 16),
            (64, 2, 1), // below one element: clamps to 1-element buckets
            (1023, 5, 100),
        ] {
            let plan = BucketPlan::derive(len, parts, bytes);
            assert_eq!(plan.len, len);
            let max_elems = if bytes == 0 { len.max(1) } else { (bytes / 4).max(1) };
            let bounds = partition(len, parts.max(1));
            // contiguous cover of [0, len) in ascending index order
            let mut at = 0usize;
            for b in &plan.buckets {
                assert_eq!(b.lo, at, "len={len} parts={parts} bytes={bytes}");
                assert!(b.hi > b.lo, "empty bucket emitted");
                assert!(b.len() <= max_elems, "bucket exceeds the size bound");
                assert!(!b.is_empty());
                // inside exactly one partition
                let (plo, phi) = bounds[b.part];
                assert!(plo <= b.lo && b.hi <= phi, "bucket straddles a partition");
                at = b.hi;
            }
            assert_eq!(at, len, "buckets must cover the space");
            // every partition boundary is a bucket boundary
            for &(plo, _) in bounds.iter().filter(|&&(lo, hi)| lo < hi) {
                assert!(
                    plo == 0 || plan.buckets.iter().any(|b| b.hi == plo),
                    "partition boundary {plo} not a bucket boundary"
                );
            }
            if len == 0 {
                assert_eq!(plan.count(), 0);
            }
        }
    }

    #[test]
    fn reduce_bucket_concat_is_bitwise_reduce_owned() {
        // the bucketing bit contract, all three schedules: slicing the
        // worker buffers per bucket, reducing each bucket independently
        // and concatenating in index order reproduces the whole-buffer
        // reduce exactly — including ragged lengths, odd worker counts
        // and bucket sizes coprime with both
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            for n in [1usize, 2, 3, 5, 7, 8] {
                for len in [1usize, 2, 17, 101, 256] {
                    for bytes in [4usize, 12, 28, 92, 4 * len, 8 * len, 0] {
                        let (bufs, _) = make_bufs(n, len);
                        let want = reduce_owned(alg, bufs.clone()).unwrap();
                        let plan = BucketPlan::derive(len, 1, bytes);
                        let mut got = Vec::with_capacity(len);
                        for b in &plan.buckets {
                            let slices: Vec<Vec<f32>> =
                                bufs.iter().map(|w| w[b.lo..b.hi].to_vec()).collect();
                            got.extend(reduce_bucket(alg, slices, b.lo, len).unwrap());
                        }
                        assert_eq!(got, want, "{alg:?} n={n} len={len} bytes={bytes}");
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_bucket_assembles_sharded_chunks_bitwise() {
        // partition-aligned buckets concatenate within each partition to
        // exactly the reduce-scatter chunk — the ZeRO-2/3 assembly the
        // pipeline's bucketed reduce performs
        for alg in [Algorithm::Naive, Algorithm::Tree, Algorithm::Ring] {
            for n in [2usize, 3, 5] {
                for parts in [2usize, 3, 5, 7] {
                    let len = 103;
                    let (bufs, _) = make_bufs(n, len);
                    let want = reduce_scatter(alg, bufs.clone(), parts).unwrap();
                    let plan = BucketPlan::derive(len, parts, 64);
                    let mut chunks = vec![Vec::new(); parts];
                    for b in &plan.buckets {
                        let slices: Vec<Vec<f32>> =
                            bufs.iter().map(|w| w[b.lo..b.hi].to_vec()).collect();
                        chunks[b.part].extend(reduce_bucket(alg, slices, b.lo, len).unwrap());
                    }
                    assert_eq!(chunks, want, "{alg:?} n={n} parts={parts}");
                }
            }
        }
    }

    #[test]
    fn reduce_bucket_single_worker_is_identity() {
        // matches reduce_mean's n == 1 early return: no 1/n scaling
        let got = reduce_bucket(Algorithm::Ring, vec![vec![1.5f32, -2.0]], 3, 10).unwrap();
        assert_eq!(got, vec![1.5, -2.0]);
        assert!(reduce_bucket(Algorithm::Ring, Vec::new(), 0, 10).is_none());
    }
}
