//! Simulated data-parallel engine.
//!
//! The paper trains with DDP over 64 A100s; the coordination pattern —
//! N workers compute gradients on disjoint shards, gradients are
//! all-reduced, the leader applies one optimizer step — is reproduced here
//! with OS threads standing in for ranks. Each worker owns its own PJRT
//! client + compiled executables (the `xla` crate's client is not `Send`),
//! receives `(phase, params, batch)` work items over a channel, and returns
//! gradient buffers. The leader drives steps through the `submit`/`collect`
//! split so the pipeline (`crate::pipeline`) can overlap its other stages
//! with the workers' compute; `compute` is the one-shot wrapper. The
//! all-reduce itself is implemented three ways (naive / tree / ring) and
//! benchmarked in `benches/allreduce.rs`. The same summation schedules
//! drive [`reduce_scatter`]/[`all_gather`], whose scattered chunks
//! concatenate bitwise to the all-reduce output (the [`Reduced`] layout
//! contract). The training stack consumes these primitives through
//! `crate::dist` — the [`Collective`] trait wraps them unchanged, and the
//! run's `Strategy` decides which layout each reduce produces.
//!
//! [`Collective`]: crate::dist::Collective

pub mod allreduce;
mod engine;

pub use allreduce::{
    all_gather, partition, reduce_bucket, reduce_mean, reduce_owned, reduce_scatter, scatter,
    sq_sum_in_order, Algorithm, Bucket, BucketPlan, Reduced,
};
pub use engine::{
    BucketCtrl, BucketMsg, BucketQueueClosed, BucketRoute, BucketTx, GradEngine, GradResult,
    GradSpace, StepMode, StepOutputs,
};
