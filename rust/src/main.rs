//! `prelora` CLI: train, evaluate, and inspect PreLoRA runs.
//!
//! ```text
//! prelora train --model vit-small --epochs 60 --preset exp2
//! prelora train --resume results/run.ckpt
//! prelora baseline --model vit-small --epochs 60
//! prelora inspect --model vit-small
//! prelora config-lint --config run.toml
//! prelora gen-config > run.toml ; prelora train --config run.toml
//! ```

use anyhow::{bail, ensure, Result};

use prelora::config::{RunConfig, StrictnessPreset};
use prelora::coordinator::resolve_watch_modules;
use prelora::manifest::Manifest;
use prelora::trainer::{Checkpoint, Trainer};
use prelora::util::args::Args;

const USAGE: &str = "usage: prelora <train|baseline|inspect|config-lint|gen-config> [flags]
  train        run with the PreLoRA controller enabled (--resume <ckpt> continues a run)
  baseline     run the full-parameter baseline (controller disabled)
  inspect      print a model's manifest summary
  config-lint  validate a run config against the model manifest without training
  gen-config   emit a default TOML config to stdout
(use `prelora <cmd> --help` for per-command flags)";

fn train_flags() -> Args {
    Args::new()
        .flag("config", "TOML config file; other flags override it")
        .flag("model", "model name under artifacts/ (default vit-small)")
        .flag("epochs", "training epochs")
        .flag("preset", "Table 1 strictness preset: exp1|exp2|exp3")
        .flag("tau", "weight-norm threshold tau (percent)")
        .flag("zeta", "loss threshold zeta (percent)")
        .flag("warmup", "warmup window w (epochs)")
        .flag("workers", "data-parallel worker count")
        .flag("allreduce", "gradient all-reduce algorithm: naive|tree|ring")
        .flag(
            "dist",
            "collective transport: local (in-memory workers) | tcp (one process per rank over --peers, bitwise-identical trajectories)",
        )
        .flag("rank", "this process's rank in the tcp group (0 hosts the rendezvous)")
        .flag(
            "peers",
            "rank-ordered host:port list, comma-separated; its length is the tcp world size",
        )
        .flag("connect-timeout-ms", "tcp connect/accept retry budget and per-op stall timeout")
        .switch("no-pipeline", "run the serial reference loop instead of the step pipeline")
        .switch(
            "zero",
            "deprecated legacy switch: shard at the historical default (stage 2) unless the config file sets train.zero.stage — prefer --zero-stage",
        )
        .flag(
            "zero-stage",
            "ZeRO stage: 0 = off, 1 = optimizer state, 2 = + gradient buffers, 3 = + parameters (each ~1/N per rank, bit-identical losses)",
        )
        .flag(
            "bucket-bytes",
            "gradient-sync bucket size in bytes (0 = whole-buffer sync); buckets overlap the reduce with backward compute, bit-identically",
        )
        .flag(
            "faults",
            "deterministic fault-injection plan, kind@epoch.step.rank[:k=v,..] entries joined \
             by ';' (e.g. \"straggle@1.0.0:ms=50;net-drop@2.1.1\"); adversity testing only",
        )
        .flag("seed", "run seed")
        .flag(
            "resume",
            "checkpoint to resume from (true mid-run continuation: restores the phase machine and adopts the checkpoint's seed)",
        )
        .flag("run-name", "label used in logs and output files")
        .flag("summary-out", "write the run summary JSON here")
        .flag("train-samples", "synthetic train-set size")
        .flag("val-samples", "synthetic val-set size")
}

fn parse_preset(name: &str) -> Result<StrictnessPreset> {
    match name {
        "exp1" => Ok(StrictnessPreset::Exp1),
        "exp2" => Ok(StrictnessPreset::Exp2),
        "exp3" => Ok(StrictnessPreset::Exp3),
        other => bail!("unknown preset {other:?} (expected exp1|exp2|exp3)"),
    }
}

fn build_config(a: &Args, prelora_enabled: bool) -> Result<RunConfig> {
    let mut cfg = match a.get("config") {
        Some(p) => RunConfig::from_toml_file(p)?,
        None => RunConfig::default(),
    };
    cfg.model = a.get_or("model", &cfg.model);
    cfg.run_name = a.get_or("run-name", &cfg.run_name);
    cfg.prelora.enabled = prelora_enabled;
    if let Some(e) = a.get_parsed::<usize>("epochs")? {
        cfg.train.epochs = e;
    }
    if let Some(p) = a.get("preset") {
        cfg.prelora = cfg.prelora.with_preset(parse_preset(p)?);
    }
    if let Some(t) = a.get_parsed::<f64>("tau")? {
        cfg.prelora.tau = t;
    }
    if let Some(z) = a.get_parsed::<f64>("zeta")? {
        cfg.prelora.zeta = z;
    }
    if let Some(w) = a.get_parsed::<usize>("warmup")? {
        cfg.prelora.warmup_epochs = w;
    }
    if let Some(w) = a.get_parsed::<usize>("workers")? {
        cfg.train.dp.workers = w;
    }
    if let Some(alg) = a.get_parsed::<prelora::dp::Algorithm>("allreduce")? {
        cfg.train.dp.allreduce = alg.to_string();
    }
    if let Some(t) = a.get("dist") {
        cfg.train.dist.transport = t.to_string();
    }
    if let Some(r) = a.get_parsed::<usize>("rank")? {
        cfg.train.dist.rank = r;
    }
    if let Some(p) = a.get("peers") {
        cfg.train.dist.peers = p
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    if let Some(ms) = a.get_parsed::<u64>("connect-timeout-ms")? {
        cfg.train.dist.connect_timeout_ms = ms;
    }
    if a.get_switch("no-pipeline") {
        cfg.train.pipeline.enabled = false;
    }
    if a.get_switch("zero") {
        // deprecated shim; run_training prints TrainConfig::lint()'s
        // deprecation warning (which names both spellings) exactly once
        cfg.train.zero.enabled = Some(true);
    }
    if let Some(stage) = a.get_parsed::<prelora::dist::ZeroStage>("zero-stage")? {
        // an explicit CLI stage overrides the config file outright,
        // including a legacy `train.zero.enabled = false` knob that would
        // otherwise take precedence over the stage (old configs always
        // carried the enabled line, and `--zero-stage 3` silently training
        // unsharded would be the worst kind of surprise)
        cfg.train.zero.enabled = None;
        cfg.train.zero.stage = Some(stage);
    }
    if let Some(bytes) = a.get_parsed::<usize>("bucket-bytes")? {
        // same override shape as --zero-stage: an explicit CLI bucket
        // size also clears a legacy `train.pipeline.overlap_reduce =
        // false` knob that would otherwise force whole-buffer sync
        cfg.train.pipeline.overlap_reduce = None;
        cfg.train.pipeline.bucket_bytes = bytes;
    }
    if let Some(spec) = a.get("faults") {
        cfg.train.faults.plan = spec.to_string();
    }
    if let Some(s) = a.get_parsed::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(n) = a.get_parsed::<usize>("train-samples")? {
        cfg.train.data.train_samples = n;
    }
    if let Some(n) = a.get_parsed::<usize>("val-samples")? {
        cfg.train.data.val_samples = n;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn run_training(raw: &[String], cmd: &str, enabled: bool) -> Result<()> {
    let a = train_flags().parse(cmd, raw)?;
    let mut cfg = build_config(&a, enabled)?;
    // configuration smells (deprecated knobs, degenerate sharding setups)
    // are loud at startup, not just under `prelora config-lint`
    for w in cfg.train.lint() {
        eprintln!("warning: {w}");
    }
    let resume_path = a
        .get("resume")
        .map(str::to_string)
        .or_else(|| cfg.train.resume.clone());
    let resume_ckpt = match &resume_path {
        Some(p) => Some(Checkpoint::load(p)?),
        None => None,
    };
    if let Some(ck) = &resume_ckpt {
        match &ck.trajectory {
            Some(tr) => {
                // the checkpoint's seed IS the serialized data-order RNG
                // state; a conflicting explicit seed cannot be honored
                if let Some(s) = a.get_parsed::<u64>("seed")? {
                    ensure!(
                        s == tr.seed,
                        "--seed {s} conflicts with the checkpoint's seed {} — resuming must \
                         keep the saving run's RNG streams (drop --seed to adopt it)",
                        tr.seed
                    );
                }
                if cfg.seed != tr.seed {
                    // a config-file seed is overridden too, but loudly: a
                    // silent override of an explicitly-written key would
                    // be inconsistent with the hard errors the restore
                    // raises for config epoch/schedule disagreements
                    eprintln!(
                        "warning: config seed {} overridden by the checkpoint's seed {} (the \
                         seed is the saved run's data-order RNG state)",
                        cfg.seed, tr.seed
                    );
                }
                cfg.seed = tr.seed;
            }
            None => eprintln!(
                "warning: {} predates checkpoint v3 — parameters and optimizer state restore, \
                 but the phase machine does not; convergence detection replays from scratch",
                resume_path.as_deref().unwrap_or("checkpoint")
            ),
        }
    }
    let summary_out = a.get("summary-out").map(str::to_string);
    let mut trainer = Trainer::new(cfg)?;
    if let Some(ck) = &resume_ckpt {
        trainer.restore(ck)?;
        eprintln!(
            "[{}] resumed from {} at epoch {} ({})",
            trainer.cfg.run_name,
            resume_path.as_deref().unwrap_or("?"),
            ck.epoch,
            trainer.phase()
        );
        // only meaningful for trajectory-carrying checkpoints: a pre-v3
        // file restores no epoch cursor, so the run still trains from
        // scratch whatever its saved epoch says
        if ck.trajectory.is_some() && ck.epoch >= trainer.cfg.train.epochs {
            eprintln!(
                "[{}] checkpoint already covers all {} configured epochs — nothing to train",
                trainer.cfg.run_name, trainer.cfg.train.epochs
            );
        }
    }
    let summary = trainer.run()?;
    println!("{}", summary.render());
    if let Some(path) = summary_out {
        std::fs::write(&path, summary.to_json())?;
        eprintln!("summary written to {path}");
    }
    Ok(())
}

/// Surface the startup validation (`prelora.convergence_modules` against
/// the manifest's telemetry set, the regular config checks, and the
/// `train.zero.*` / `train.pipeline.*` block lint — stage range, worker
/// count vs. partition sanity) without starting a run — a misspelled
/// module or a degenerate sharding setup should cost seconds, not a
/// training job. Validates strictly even when the controller is disabled.
fn config_lint(raw: &[String]) -> Result<()> {
    let a = Args::new()
        .flag("config", "TOML config file to lint (default: built-in defaults)")
        .flag("model", "model name under artifacts/ (overrides the config)")
        .flag("artifacts-dir", "artifacts root (overrides the config)")
        .parse("config-lint", raw)?;
    let mut cfg = match a.get("config") {
        Some(p) => RunConfig::from_toml_file(p)?,
        None => RunConfig::default(),
    };
    cfg.model = a.get_or("model", &cfg.model);
    cfg.artifacts_dir = a.get_or("artifacts-dir", &cfg.artifacts_dir);
    cfg.validate()?;
    let mut warnings = cfg.train.lint();
    let manifest = Manifest::load(cfg.model_dir())?;
    // partition sanity needs the manifest: more ranks than parameters
    // means empty shards (legal — partition() pads — but never intended)
    let stage = cfg.train.zero.effective_stage();
    if stage != prelora::dist::ZeroStage::Off && cfg.train.dp.workers > manifest.base.size {
        warnings.push(format!(
            "train.dp.workers = {} exceeds the model's {} base parameters — most ranks would \
             own empty partitions",
            cfg.train.dp.workers, manifest.base.size
        ));
    }
    let modules = resolve_watch_modules(&cfg.prelora, &manifest, true)?;
    for w in &warnings {
        eprintln!("warning: {w}");
    }
    println!(
        "config ok: model {}, zero stage {}, strategy {}, convergence test watches {} module(s): {}{}",
        cfg.model,
        stage,
        cfg.prelora.strategy.as_str(),
        modules.len(),
        modules.join(", "),
        if warnings.is_empty() {
            String::new()
        } else {
            format!(" ({} warning(s))", warnings.len())
        }
    );
    Ok(())
}

fn inspect(raw: &[String]) -> Result<()> {
    let a = Args::new()
        .flag("model", "model name (default vit-small)")
        .flag("artifacts-dir", "artifacts root (default artifacts)")
        .parse("inspect", raw)?;
    let model = a.get_or("model", "vit-small");
    let dir = a.get_or("artifacts-dir", "artifacts");
    let m = Manifest::load(std::path::Path::new(&dir).join(&model))?;
    println!("model {} (backend {}, seed {})", m.model, m.backend, m.seed);
    let c = &m.config;
    println!(
        "  dims: {}x{}x{} patch {} hidden {} depth {} heads {} mlp {} classes {} batch {}",
        c.image_size,
        c.image_size,
        c.in_channels,
        c.patch_size,
        c.hidden_dim,
        c.depth,
        c.num_heads,
        c.mlp_dim,
        c.num_classes,
        c.batch_size
    );
    println!(
        "  base params: {} | lora params (r_max={}): {} | adapters: {}",
        m.base.size,
        c.r_max,
        m.lora.size,
        m.adapters.len()
    );
    println!("  rank buckets: {:?}", c.rank_buckets);
    for (name, a) in &m.artifacts {
        let size = std::fs::metadata(m.artifact_path(name)?)
            .map(|md| md.len())
            .unwrap_or(0);
        println!(
            "  artifact {name}: {} -> {} ({} KiB)",
            a.inputs.join(","),
            a.outputs.join(","),
            size / 1024
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "train" => run_training(rest, "train", true),
        "baseline" => run_training(rest, "baseline", false),
        "inspect" => inspect(rest),
        "config-lint" => config_lint(rest),
        "gen-config" => {
            println!("{}", RunConfig::default().to_toml());
            Ok(())
        }
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
}
