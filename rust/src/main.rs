//! `prelora` CLI: train, evaluate, and inspect PreLoRA runs.
//!
//! ```text
//! prelora train --model vit-small --epochs 60 --preset exp2
//! prelora baseline --model vit-small --epochs 60
//! prelora inspect --model vit-small
//! prelora gen-config > run.toml ; prelora train --config run.toml
//! ```

use anyhow::{bail, Result};

use prelora::config::{RunConfig, StrictnessPreset};
use prelora::manifest::Manifest;
use prelora::trainer::Trainer;
use prelora::util::args::Args;

const USAGE: &str = "usage: prelora <train|baseline|inspect|gen-config> [flags]
  train       run with the PreLoRA controller enabled
  baseline    run the full-parameter baseline (controller disabled)
  inspect     print a model's manifest summary
  gen-config  emit a default TOML config to stdout
(use `prelora <cmd> --help` for per-command flags)";

fn train_flags() -> Args {
    Args::new()
        .flag("config", "TOML config file; other flags override it")
        .flag("model", "model name under artifacts/ (default vit-small)")
        .flag("epochs", "training epochs")
        .flag("preset", "Table 1 strictness preset: exp1|exp2|exp3")
        .flag("tau", "weight-norm threshold tau (percent)")
        .flag("zeta", "loss threshold zeta (percent)")
        .flag("warmup", "warmup window w (epochs)")
        .flag("workers", "data-parallel worker count")
        .flag("allreduce", "gradient all-reduce algorithm: naive|tree|ring")
        .switch("no-pipeline", "run the serial reference loop instead of the step pipeline")
        .switch(
            "zero",
            "shard optimizer state (and, at the default stage 2, gradient buffers) across workers: ~1/N state per worker, bit-identical losses",
        )
        .flag(
            "zero-stage",
            "ZeRO stage: 1 = optimizer state only, 2 = + gradient buffers (implies --zero)",
        )
        .flag("seed", "run seed")
        .flag("run-name", "label used in logs and output files")
        .flag("summary-out", "write the run summary JSON here")
        .flag("train-samples", "synthetic train-set size")
        .flag("val-samples", "synthetic val-set size")
}

fn parse_preset(name: &str) -> Result<StrictnessPreset> {
    match name {
        "exp1" => Ok(StrictnessPreset::Exp1),
        "exp2" => Ok(StrictnessPreset::Exp2),
        "exp3" => Ok(StrictnessPreset::Exp3),
        other => bail!("unknown preset {other:?} (expected exp1|exp2|exp3)"),
    }
}

fn build_config(a: &Args, prelora_enabled: bool) -> Result<RunConfig> {
    let mut cfg = match a.get("config") {
        Some(p) => RunConfig::from_toml_file(p)?,
        None => RunConfig::default(),
    };
    cfg.model = a.get_or("model", &cfg.model);
    cfg.run_name = a.get_or("run-name", &cfg.run_name);
    cfg.prelora.enabled = prelora_enabled;
    if let Some(e) = a.get_parsed::<usize>("epochs")? {
        cfg.train.epochs = e;
    }
    if let Some(p) = a.get("preset") {
        cfg.prelora = cfg.prelora.with_preset(parse_preset(p)?);
    }
    if let Some(t) = a.get_parsed::<f64>("tau")? {
        cfg.prelora.tau = t;
    }
    if let Some(z) = a.get_parsed::<f64>("zeta")? {
        cfg.prelora.zeta = z;
    }
    if let Some(w) = a.get_parsed::<usize>("warmup")? {
        cfg.prelora.warmup_epochs = w;
    }
    if let Some(w) = a.get_parsed::<usize>("workers")? {
        cfg.train.dp.workers = w;
    }
    if let Some(alg) = a.get_parsed::<prelora::dp::Algorithm>("allreduce")? {
        cfg.train.dp.allreduce = alg.to_string();
    }
    if a.get_switch("no-pipeline") {
        cfg.train.pipeline.enabled = false;
    }
    if a.get_switch("zero") {
        cfg.train.zero.enabled = true;
    }
    if let Some(stage) = a.get_parsed::<u8>("zero-stage")? {
        cfg.train.zero.enabled = true;
        cfg.train.zero.stage = stage;
    }
    if let Some(s) = a.get_parsed::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(n) = a.get_parsed::<usize>("train-samples")? {
        cfg.train.data.train_samples = n;
    }
    if let Some(n) = a.get_parsed::<usize>("val-samples")? {
        cfg.train.data.val_samples = n;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn run_training(raw: &[String], cmd: &str, enabled: bool) -> Result<()> {
    let a = train_flags().parse(cmd, raw)?;
    let cfg = build_config(&a, enabled)?;
    let summary_out = a.get("summary-out").map(str::to_string);
    let mut trainer = Trainer::new(cfg)?;
    let summary = trainer.run()?;
    println!("{}", summary.render());
    if let Some(path) = summary_out {
        std::fs::write(&path, summary.to_json())?;
        eprintln!("summary written to {path}");
    }
    Ok(())
}

fn inspect(raw: &[String]) -> Result<()> {
    let a = Args::new()
        .flag("model", "model name (default vit-small)")
        .flag("artifacts-dir", "artifacts root (default artifacts)")
        .parse("inspect", raw)?;
    let model = a.get_or("model", "vit-small");
    let dir = a.get_or("artifacts-dir", "artifacts");
    let m = Manifest::load(std::path::Path::new(&dir).join(&model))?;
    println!("model {} (backend {}, seed {})", m.model, m.backend, m.seed);
    let c = &m.config;
    println!(
        "  dims: {}x{}x{} patch {} hidden {} depth {} heads {} mlp {} classes {} batch {}",
        c.image_size,
        c.image_size,
        c.in_channels,
        c.patch_size,
        c.hidden_dim,
        c.depth,
        c.num_heads,
        c.mlp_dim,
        c.num_classes,
        c.batch_size
    );
    println!(
        "  base params: {} | lora params (r_max={}): {} | adapters: {}",
        m.base.size,
        c.r_max,
        m.lora.size,
        m.adapters.len()
    );
    println!("  rank buckets: {:?}", c.rank_buckets);
    for (name, a) in &m.artifacts {
        let size = std::fs::metadata(m.artifact_path(name)?)
            .map(|md| md.len())
            .unwrap_or(0);
        println!(
            "  artifact {name}: {} -> {} ({} KiB)",
            a.inputs.join(","),
            a.outputs.join(","),
            size / 1024
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "train" => run_training(rest, "train", true),
        "baseline" => run_training(rest, "baseline", false),
        "inspect" => inspect(rest),
        "gen-config" => {
            println!("{}", RunConfig::default().to_toml());
            Ok(())
        }
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
}
