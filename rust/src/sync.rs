//! Sync-primitive indirection for model checking.
//!
//! Concurrency-bearing modules (`dp`, `pipeline`) import their channels,
//! `Arc`, and thread handles from here instead of `std::sync` directly, so
//! a model checker can substitute instrumented primitives under
//! `--cfg loom` without touching the call sites. The `loom` branch is the
//! documented hook point for [loom](https://docs.rs/loom) once the build
//! environment can fetch it; it is `cfg`'d out so the tree never depends
//! on the crate. Two gaps make the hook insufficient on its own today:
//! loom's `mpsc` has no `sync_channel`, and `loom::thread` has no
//! `Builder` — both are load-bearing in the bucket-sync protocol (bounded
//! publish queue, named workers). The protocol's interleavings are instead
//! verified exhaustively by the vendored checker in [`crate::mc`] against
//! faithful models of these primitives (`rust/tests/loom_bucket.rs`); the
//! shim keeps production code honest about *which* primitives those models
//! must mirror.

#[cfg(loom)]
pub(crate) use loom::sync::{mpsc, Arc};
#[cfg(loom)]
pub(crate) use loom::thread;

#[cfg(not(loom))]
pub(crate) use std::sync::{mpsc, Arc};
#[cfg(not(loom))]
pub(crate) use std::thread;
