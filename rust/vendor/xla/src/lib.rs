//! Offline **stub** of the vendored `xla` crate (xla-rs bindings over
//! xla_extension 0.5.1).
//!
//! The build environment has no network access and the real vendored
//! bindings are not checked in, so this crate provides the exact API
//! surface `prelora::runtime` consumes with inert implementations:
//! client construction succeeds (so engines and worker threads wire up),
//! but anything that would parse, compile or execute HLO returns an
//! error. Pure-Rust paths — optimizers, all-reduce, convergence,
//! checkpointing, config — build and test normally; artifact-dependent
//! tests fail at `HloModuleProto::from_text_file` with a clear message,
//! exactly as they fail on a machine without built artifacts.
//!
//! To run real artifacts, replace this directory with the actual
//! xla-rs checkout (same crate name/version) — no caller changes needed.

use std::fmt;

/// Error type mirroring xla-rs's: `Display + std::error::Error`, so
/// `anyhow::Context` applies unchanged at the call sites.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "XLA runtime unavailable ({what}): this build uses the in-tree stub of the vendored \
         `xla` crate (rust/vendor/xla); drop the real xla-rs bindings into that directory to \
         compile and execute HLO artifacts"
    ))
}

/// PJRT client handle. Construction succeeds so the worker pool and
/// runtime caches wire up; compilation is where the stub stops.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HLO text parsing"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// Host literal. Inputs can be constructed (they are plain copies in the
/// real bindings too); reading outputs is unreachable because execution
/// errors first.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Self {
        Self
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("literal read"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple decompose"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_compile_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let err = c.compile(&XlaComputation::from_proto(&HloModuleProto)).unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn input_literals_construct() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[1, 2]).is_ok());
        assert!(Literal::vec1(&[1i32]).to_vec::<f32>().is_err());
    }
}
