//! prelora-lint: determinism and concurrency invariant checker for the
//! prelora tree.
//!
//! Usage (from `rust/`):
//!
//! ```text
//! cargo run -p prelora-lint                      # lint rust/src, exit 1 on findings
//! cargo run -p prelora-lint -- --list-rules
//! cargo run -p prelora-lint -- --root other/src
//! cargo run -p prelora-lint -- --format json     # machine-readable diagnostics
//! cargo run -p prelora-lint -- --format github   # ::error annotations for CI
//! cargo run -p prelora-lint -- --graph           # thread/channel topology as dot
//! ```
//!
//! Text output is one line per finding, `RULE src/path.rs:line message`,
//! in deterministic (path, line, rule) order — the lint practices what it
//! preaches. `--format json` emits the same findings under the stable
//! `prelora-lint/1` schema; `--format github` emits workflow-command
//! annotations with paths rebased by `--path-prefix` (default `rust/`)
//! so they land on the right files in a PR. `--graph` prints the
//! extracted thread/channel topology as graphviz dot and exits 0.
//!
//! PL001–PL005 run per file; PL006–PL010 run on the crate-wide program
//! model (see `model`). PL010 additionally reads `tests/adversity.rs`
//! next to the source root, when present.

mod graph;
mod lexer;
mod model;
mod rules;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Clone, Copy, PartialEq)]
enum Format {
    Text,
    Json,
    Github,
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut format = Format::Text;
    let mut want_graph = false;
    let mut path_prefix = "rust/".to_string();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--list-rules" => {
                for (id, summary) in rules::RULES {
                    println!("{id}  {summary}");
                }
                return ExitCode::SUCCESS;
            }
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--root needs a directory argument");
                    return ExitCode::from(2);
                }
            },
            "--format" => match args.next().as_deref() {
                Some("text") => format = Format::Text,
                Some("json") => format = Format::Json,
                Some("github") => format = Format::Github,
                other => {
                    eprintln!("--format needs one of text|json|github, got {other:?}");
                    return ExitCode::from(2);
                }
            },
            "--path-prefix" => match args.next() {
                Some(p) => path_prefix = p,
                None => {
                    eprintln!("--path-prefix needs a value (may be empty via --path-prefix \"\")");
                    return ExitCode::from(2);
                }
            },
            "--graph" => want_graph = true,
            other => {
                eprintln!(
                    "unknown argument: {other} (try --list-rules, --root <dir>, \
                     --format text|json|github, --path-prefix <p>, --graph)"
                );
                return ExitCode::from(2);
            }
        }
    }
    // Default to the prelora sources relative to this crate's manifest, so
    // the tool works from any cwd via `cargo run -p prelora-lint`.
    let default_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let root = root.unwrap_or(default_root);

    let mut paths = Vec::new();
    if let Err(e) = walk(&root, &mut paths) {
        eprintln!("prelora-lint: cannot scan {}: {e}", root.display());
        return ExitCode::from(2);
    }
    paths.sort();

    let mut files: Vec<(String, lexer::SourceFile)> = Vec::new();
    for path in &paths {
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("prelora-lint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        files.push((rel, lexer::lex(&src)));
    }

    let model = model::Model::build(&files);

    if want_graph {
        print!("{}", graph::render(&model));
        return ExitCode::SUCCESS;
    }

    // The adversity matrix lives at <root>/../tests/adversity.rs in the
    // repo layout (rust/src -> rust/tests); PL010 degrades gracefully
    // when it is absent.
    let adversity = std::fs::read_to_string(root.join("../tests/adversity.rs")).ok();

    let mut findings: Vec<(String, rules::Finding)> = Vec::new();
    for (rel, sf) in &files {
        for f in rules::check_file(rel, sf) {
            findings.push((rel.clone(), f));
        }
    }
    for (fi, f) in rules::check_crate(&files, &model, adversity.as_deref()) {
        findings.push((files[fi].0.clone(), f));
    }
    findings.sort_by(|a, b| (a.0.as_str(), a.1.line, a.1.rule).cmp(&(b.0.as_str(), b.1.line, b.1.rule)));

    emit(format, &findings, files.len(), &path_prefix);
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn emit(format: Format, findings: &[(String, rules::Finding)], n_files: usize, prefix: &str) {
    match format {
        Format::Text => {
            for (rel, f) in findings {
                println!("{} src/{}:{} {}", f.rule, rel, f.line, f.message);
            }
            if findings.is_empty() {
                println!("prelora-lint: clean ({n_files} files)");
            } else {
                println!(
                    "prelora-lint: {} finding(s) — rule catalog: docs/static-analysis.md",
                    findings.len()
                );
            }
        }
        Format::Json => {
            // Hand-rolled serialization: the tool is dependency-free by
            // design, and the schema is pinned by an integration test.
            let mut out = String::from("{\"schema\":\"prelora-lint/1\",\"findings\":[");
            for (i, (rel, f)) in findings.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{{\"rule\":{},\"file\":{},\"line\":{},\"message\":{}}}",
                    json_str(f.rule),
                    json_str(&format!("src/{rel}")),
                    f.line,
                    json_str(&f.message)
                ));
            }
            out.push_str(&format!("],\"count\":{}}}", findings.len()));
            println!("{out}");
        }
        Format::Github => {
            for (rel, f) in findings {
                println!(
                    "::error file={prefix}src/{rel},line={},title={}::{}",
                    f.line,
                    f.rule,
                    gh_escape(&f.message)
                );
            }
            if findings.is_empty() {
                println!("prelora-lint: clean ({n_files} files)");
            }
        }
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Workflow-command message escaping (the data portion of `::error`).
fn gh_escape(s: &str) -> String {
    s.replace('%', "%25").replace('\r', "%0D").replace('\n', "%0A")
}

/// Collect `.rs` files under `dir`. Directory entries are sorted so the
/// scan order (and therefore the report order) is stable across machines.
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}
