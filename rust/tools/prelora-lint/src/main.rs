//! prelora-lint: determinism-invariant checker for the prelora tree.
//!
//! Usage (from `rust/`):
//!
//! ```text
//! cargo run -p prelora-lint                # lint rust/src, exit 1 on findings
//! cargo run -p prelora-lint -- --list-rules
//! cargo run -p prelora-lint -- --root other/src
//! ```
//!
//! Output is one line per finding, `RULE src/path.rs:line message`, in
//! deterministic (path, line) order — the lint practices what it preaches.

mod lexer;
mod rules;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--list-rules" => {
                for (id, summary) in rules::RULES {
                    println!("{id}  {summary}");
                }
                return ExitCode::SUCCESS;
            }
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--root needs a directory argument");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("unknown argument: {other} (try --list-rules or --root <dir>)");
                return ExitCode::from(2);
            }
        }
    }
    // Default to the prelora sources relative to this crate's manifest, so
    // the tool works from any cwd via `cargo run -p prelora-lint`.
    let default_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let root = root.unwrap_or(default_root);

    let mut files = Vec::new();
    if let Err(e) = walk(&root, &mut files) {
        eprintln!("prelora-lint: cannot scan {}: {e}", root.display());
        return ExitCode::from(2);
    }
    files.sort();

    let mut total = 0usize;
    for path in &files {
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("prelora-lint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let lexed = lexer::lex(&src);
        for f in rules::check_file(&rel, &lexed) {
            println!("{} src/{}:{} {}", f.rule, rel, f.line, f.message);
            total += 1;
        }
    }

    if total == 0 {
        println!("prelora-lint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        println!("prelora-lint: {total} finding(s) — rule catalog: docs/static-analysis.md");
        ExitCode::FAILURE
    }
}

/// Collect `.rs` files under `dir`. Directory entries are sorted so the
/// scan order (and therefore the report order) is stable across machines.
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}
