//! A line-oriented Rust lexer — just enough to separate code from prose.
//!
//! Rules in this tool are substring checks, so the lexer's one job is to
//! make sure those substrings can only match real code: comments are
//! stripped into a separate per-line `comment` field (where the allow /
//! thread-marker annotations live), and string/char literal *contents* are
//! blanked while their delimiters stay, so `"HashMap"` in a log message
//! never trips PL001. It also brace-matches `#[cfg(test)]` items so rules
//! can skip test regions.
//!
//! It is not a full lexer: no macro expansion, no `include!`, and the
//! lifetime-vs-char-literal split is a two-character lookahead heuristic.
//! That is fine for a lint that gates a single known tree — the unit tests
//! below pin the cases the prelora sources actually contain.

/// One source line, split into rule-checkable parts.
#[derive(Debug, Default)]
pub struct Line {
    /// Code with comments removed and string/char contents blanked
    /// (delimiters kept), so substring rules cannot match prose.
    pub code: String,
    /// Comment text carried by this line (line and block comments).
    pub comment: String,
    /// The original line, verbatim. Rules that must see *into* string
    /// literals (PL009's interpolated error context, the topology
    /// graph's `.name("...")` thread labels) read this instead of
    /// `code` — never for pattern bans, which stay prose-proof.
    pub raw: String,
}

/// A lexed file plus its test-region map.
pub struct SourceFile {
    pub lines: Vec<Line>,
    /// `true` for lines belonging to a `#[cfg(test)]` item (attribute
    /// line through the item's closing brace).
    pub in_test: Vec<bool>,
}

#[derive(Clone, Copy)]
enum State {
    Code,
    LineComment,
    /// Nested block comments carry their depth.
    BlockComment(u32),
    Str,
    /// Raw strings carry their `#` count.
    RawStr(u32),
    CharLit,
}

pub fn lex(src: &str) -> SourceFile {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    state = State::Str;
                    i += 1;
                } else if let Some(hashes) = (c == 'r').then(|| raw_str_hashes(&chars, i)).flatten() {
                    cur.code.push_str("r\"");
                    state = State::RawStr(hashes);
                    i += 2 + hashes as usize;
                } else if c == '\'' {
                    // Lifetime (`'a`, `'static`) vs char literal (`'x'`,
                    // `'\n'`): an identifier char followed by anything but
                    // a closing quote means lifetime.
                    let next = chars.get(i + 1);
                    let after = chars.get(i + 2);
                    let lifetime = next.is_some_and(|n| n.is_alphanumeric() || *n == '_')
                        && after != Some(&'\'');
                    cur.code.push('\'');
                    if !lifetime {
                        state = State::CharLit;
                    }
                    i += 1;
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    state = if depth == 1 { State::Code } else { State::BlockComment(depth - 1) };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // Keep an escaped newline visible to the line loop so
                    // line numbers stay aligned.
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    cur.code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    cur.code.push('"');
                    state = State::Code;
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' {
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '\'' {
                    cur.code.push('\'');
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    // Attach the verbatim text per line. `lines` was built by splitting on
    // the same `\n`s, so the indices agree; `get` keeps a (hypothetical)
    // miscount from ever panicking on a truncated input.
    let raws: Vec<&str> = src.split('\n').collect();
    for (i, line) in lines.iter_mut().enumerate() {
        line.raw = raws.get(i).copied().unwrap_or("").to_string();
    }
    let in_test = mark_tests(&lines);
    SourceFile { lines, in_test }
}

/// `Some(n)` when position `i` (an `r`) starts a raw string with `n` hashes.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<u32> {
    let mut j = i + 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(hashes)
}

fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (0..hashes as usize).all(|k| chars.get(i + 1 + k) == Some(&'#'))
}

/// Mark every line of each `#[cfg(test)]` item by brace-matching its body.
/// Strings and comments are already stripped, so every brace in `code` is
/// structural.
fn mark_tests(lines: &[Line]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0i64;
        let mut opened = false;
        let mut j = i;
        while j < lines.len() {
            in_test[j] = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    in_test
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        lex(src).lines.into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn comments_are_stripped_into_the_comment_field() {
        let f = lex("let x = 1; // HashMap here is prose\n/* and\nhere */ let y = 2;\n");
        assert_eq!(f.lines[0].code.trim_end(), "let x = 1;");
        assert!(f.lines[0].comment.contains("HashMap"));
        assert!(f.lines[1].comment.contains("and"));
        assert!(f.lines[2].comment.contains("here"));
        assert_eq!(f.lines[2].code.trim(), "let y = 2;");
    }

    #[test]
    fn string_contents_are_blanked_but_delimiters_survive() {
        let got = code_of("let s = \"HashMap .unwrap() // nope\"; s.len();\n");
        assert_eq!(got[0], "let s = \"\"; s.len();");
    }

    #[test]
    fn raw_strings_and_escapes_do_not_leak() {
        let got = code_of("let a = r#\"x \" HashMap\"#; let b = \"q\\\"HashSet\";\n");
        assert_eq!(got[0], "let a = r\"\"; let b = \"\";");
    }

    #[test]
    fn lifetimes_are_code_but_char_literals_are_blanked() {
        let got = code_of("fn f<'a>(x: &'static str) -> char { 'y' }\n");
        assert_eq!(got[0], "fn f<'a>(x: &'static str) -> char { '' }");
        let got = code_of("let c = '\\n'; let d = 'Z';\n");
        assert_eq!(got[0], "let c = ''; let d = '';");
    }

    #[test]
    fn multiline_strings_keep_line_numbers_aligned() {
        let f = lex("let s = \"one\ntwo\nthree\"; let t = 4;\n");
        assert_eq!(f.lines.len(), 3);
        assert_eq!(f.lines[2].code, "\"; let t = 4;");
    }

    #[test]
    fn cfg_test_region_is_brace_matched() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let f = lex(src);
        assert_eq!(f.in_test, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn nested_block_comments_terminate_correctly() {
        let got = code_of("/* outer /* inner */ still */ let z = 1;\n");
        assert_eq!(got[0].trim(), "let z = 1;");
    }

    #[test]
    fn raw_lines_match_the_input_verbatim() {
        let src = "let s = \"HashMap\"; // prose\nlet t = 1;\n";
        let f = lex(src);
        assert_eq!(f.lines[0].raw, "let s = \"HashMap\"; // prose");
        assert_eq!(f.lines[1].raw, "let t = 1;");
    }

    // ---- hardening: the lexer is fed untrusted shapes below ----------
    //
    // The properties every input must satisfy, panics aside:
    //  * one lexed line per `\n` in the input; the final unterminated
    //    line may be dropped only when it carries no code or comment
    //    text (empty, or wholly inside a string literal — zero rule
    //    surface either way), so rule line numbers stay honest;
    //  * `in_test` is index-aligned with `lines`;
    //  * `raw` round-trips the input text for every line.
    fn assert_lex_invariants(src: &str) {
        let f = lex(src);
        let raws: Vec<&str> = src.split('\n').collect();
        assert!(
            f.lines.len() == raws.len() || f.lines.len() + 1 == raws.len(),
            "line count drifted: {} lexed vs {} input",
            f.lines.len(),
            raws.len()
        );
        assert_eq!(f.in_test.len(), f.lines.len());
        for (i, line) in f.lines.iter().enumerate() {
            assert_eq!(line.raw, raws[i], "raw text drifted at line {}", i + 1);
        }
    }

    /// Same xorshift generator the frame fuzzer uses — deterministic, no
    /// deps, and seeds are printed by the assert message on failure.
    struct XorShift64(u64);
    impl XorShift64 {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
    }

    #[test]
    fn arbitrary_byte_strings_never_panic_the_lexer() {
        let mut rng = XorShift64(0x5eed_1e4e_a11_f00d);
        // Bias the alphabet toward the lexer's state-machine triggers so
        // the walk actually exercises string/comment/raw transitions.
        let spice = [b'"', b'\'', b'/', b'*', b'\\', b'r', b'#', b'\n', b'{', b'}'];
        for _ in 0..512 {
            let len = (rng.next() % 300) as usize;
            let bytes: Vec<u8> = (0..len)
                .map(|_| {
                    let r = rng.next();
                    if r % 3 == 0 {
                        spice[(r / 3) as usize % spice.len()]
                    } else {
                        (r >> 16) as u8
                    }
                })
                .collect();
            let src = String::from_utf8_lossy(&bytes);
            assert_lex_invariants(&src);
        }
    }

    #[test]
    fn every_prefix_truncation_of_real_sources_lexes_cleanly() {
        // The lint's own sources are real Rust with raw strings, nested
        // comments, lifetimes and char literals. Every byte-prefix of the
        // leading window must lex without panicking, and the full file
        // must too at a byte stride (full quadratic cost is pointless).
        for src in [include_str!("lexer.rs"), include_str!("rules.rs"), include_str!("model.rs")] {
            let bytes = src.as_bytes();
            let window = bytes.len().min(2048);
            for cut in 0..=window {
                assert_lex_invariants(&String::from_utf8_lossy(&bytes[..cut]));
            }
            let mut cut = window;
            while cut < bytes.len() {
                assert_lex_invariants(&String::from_utf8_lossy(&bytes[..cut]));
                cut += 97;
            }
            assert_lex_invariants(src);
        }
    }

    #[test]
    fn truncation_inside_every_state_is_harmless() {
        for src in [
            "let s = \"unterminated",
            "let s = \"escape at eof \\",
            "let r = r#\"raw unterminated",
            "let r = r##\"raw with short close\"#",
            "/* block /* nested and unterminated",
            "// line comment at eof",
            "let c = '",
            "let c = '\\",
            "let l = &'",
            "r",
            "r#",
            "r#\"",
        ] {
            assert_lex_invariants(src);
        }
    }

    #[test]
    fn lifetime_char_ambiguity_is_resolved_by_lookahead() {
        // lifetimes stay code (visible to rules) …
        let got = code_of("impl<'a, 'b: 'a> Foo<'a> for &'b mut T {}\n");
        assert_eq!(got[0], "impl<'a, 'b: 'a> Foo<'a> for &'b mut T {}");
        // … single-char and escaped literals are blanked …
        let got = code_of("let v = ['r', '\\'', '_', 'y'];\n");
        assert_eq!(got[0], "let v = ['', '', '', ''];");
        // … and a lifetime bound hard against a shippable token parses on.
        let got = code_of("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert_eq!(got[0], "fn f<'a>(x: &'a str) -> &'a str { x }");
    }

    #[test]
    fn raw_string_hash_depths_nest_and_close_exactly() {
        let got = code_of("let a = r##\"has \"# inside\"##; let b = r\"plain\";\n");
        assert_eq!(got[0], "let a = r\"\"; let b = r\"\";");
        // multi-line raw strings keep line alignment
        let f = lex("let a = r#\"one\ntwo\"#; let b = 2;\n");
        assert_eq!(f.lines.len(), 2);
        assert_eq!(f.lines[1].code, "\"; let b = 2;");
    }
}
