//! Dot-format rendering of the concurrency topology extracted by
//! [`crate::model`]: one box per spawned thread (named per PL005), one
//! ellipse per function that owns a thread or channel endpoint, dotted
//! spawn edges, and one edge per channel from the sender's owner to the
//! receiver's owner (dashed when the channel is unbounded).
//!
//! Output is deterministic: the model records spawns, channels, and
//! functions in sorted-file, top-to-bottom source order, and rendering
//! walks them in that order.

use crate::model::Model;

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Node id for the owner of a source location: the capturing spawn's
/// thread node when the endpoint lives inside a spawn body, else the
/// enclosing function's node, else a per-file fallback node.
fn owner_id(spawn: Option<usize>, func: Option<usize>, file: usize) -> String {
    match (spawn, func) {
        (Some(s), _) => format!("t{s}"),
        (None, Some(f)) => format!("f{f}"),
        (None, None) => format!("file{file}"),
    }
}

pub fn render(model: &Model) -> String {
    let mut out = String::new();
    out.push_str("digraph prelora_topology {\n");
    out.push_str("    rankdir=LR;\n");
    out.push_str("    node [fontsize=10];\n");

    // Function nodes referenced by any spawn site or channel endpoint.
    let mut fn_nodes: Vec<usize> = Vec::new();
    let mut file_nodes: Vec<usize> = Vec::new();
    let mut want_fn = |idx: Option<usize>, file: usize, fns: &mut Vec<usize>, fls: &mut Vec<usize>| match idx {
        Some(i) => {
            if !fns.contains(&i) {
                fns.push(i);
            }
        }
        None => {
            if !fls.contains(&file) {
                fls.push(file);
            }
        }
    };
    for sp in &model.spawns {
        want_fn(sp.func, sp.file, &mut fn_nodes, &mut file_nodes);
    }
    for ch in &model.channels {
        if ch.tx_spawn.is_none() {
            want_fn(ch.func, ch.file, &mut fn_nodes, &mut file_nodes);
        }
        if ch.rx_spawn.is_none() {
            want_fn(ch.func, ch.file, &mut fn_nodes, &mut file_nodes);
        }
    }
    fn_nodes.sort_unstable();
    file_nodes.sort_unstable();

    for &i in &fn_nodes {
        let f = &model.functions[i];
        out.push_str(&format!(
            "    f{i} [shape=ellipse, label=\"fn {}\\n{}\"];\n",
            esc(&f.name),
            esc(&model.files[f.file])
        ));
    }
    for &fl in &file_nodes {
        out.push_str(&format!(
            "    file{fl} [shape=ellipse, style=dashed, label=\"{}\"];\n",
            esc(&model.files[fl])
        ));
    }

    // Thread nodes + spawn edges.
    for (si, sp) in model.spawns.iter().enumerate() {
        let name = sp.thread_name.as_deref().unwrap_or("unnamed");
        let marker = if sp.marked { "joined" } else { "UNMARKED" };
        out.push_str(&format!(
            "    t{si} [shape=box, label=\"{}\\n{}:{}\\n[{}]\"];\n",
            esc(name),
            esc(&model.files[sp.file]),
            sp.line,
            marker
        ));
        let from = owner_id(None, sp.func, sp.file);
        out.push_str(&format!("    {from} -> t{si} [style=dotted, label=\"spawn\"];\n"));
    }

    // Channel edges: sender owner -> receiver owner.
    for ch in &model.channels {
        let tx = ch.tx.as_deref().unwrap_or("_");
        let rx = ch.rx.as_deref().unwrap_or("_");
        let cap = match (&ch.bounded, &ch.capacity) {
            (true, Some(c)) => format!("cap={}", c),
            (true, None) => "bounded".to_string(),
            (false, _) => "unbounded".to_string(),
        };
        let style = if ch.bounded { "solid" } else { "dashed" };
        let from = owner_id(ch.tx_spawn, ch.func, ch.file);
        let to = owner_id(ch.rx_spawn, ch.func, ch.file);
        out.push_str(&format!(
            "    {from} -> {to} [style={style}, label=\"{} to {}\\n{}\\n{}:{}\"];\n",
            esc(tx),
            esc(rx),
            esc(&cap),
            esc(&model.files[ch.file]),
            ch.line
        ));
    }

    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn model_of(files: &[(&str, &str)]) -> Model {
        let lexed: Vec<(String, crate::lexer::SourceFile)> =
            files.iter().map(|(r, s)| (r.to_string(), lex(s))).collect();
        Model::build(&lexed)
    }

    #[test]
    fn threads_channels_and_owners_all_appear() {
        let m = model_of(&[(
            "dist/worker.rs",
            "const CAP: usize = 4;\n\
             fn start(&self) {\n\
                 let (tx, rx) = mpsc::sync_channel::<u8>(CAP);\n\
                 // lint: thread: joined — Drop joins.\n\
                 let j = thread::Builder::new()\n\
                     .name(\"pump-1\".into())\n\
                     .spawn(move || {\n\
                         while let Ok(v) = rx.recv() {\n\
                             handle(v);\n\
                         }\n\
                     })\n\
                     .unwrap();\n\
             }\n",
        )]);
        let dot = render(&m);
        assert!(dot.contains("digraph prelora_topology"));
        assert!(dot.contains("pump-1"), "thread name missing:\n{dot}");
        assert!(dot.contains("[joined]"));
        assert!(dot.contains("fn start"), "owner function missing:\n{dot}");
        assert!(dot.contains("tx to rx"), "channel endpoints missing:\n{dot}");
        assert!(dot.contains("cap=CAP"));
        // the receiver is drained inside the spawn body: edge must target t0
        assert!(dot.contains("-> t0 [style=solid"), "rx owner should be the thread:\n{dot}");
    }

    #[test]
    fn unbounded_channels_render_dashed() {
        let m = model_of(&[(
            "runtime.rs",
            "fn wire(&self) {\n    let (tx, rx) = mpsc::channel::<u8>();\n    keep(tx, rx);\n}\n",
        )]);
        let dot = render(&m);
        assert!(dot.contains("style=dashed"));
        assert!(dot.contains("unbounded"));
    }

    #[test]
    fn unmarked_spawns_are_called_out() {
        let m = model_of(&[("runtime.rs", "fn go() {\n    std::thread::spawn(|| work());\n}\n")]);
        let dot = render(&m);
        assert!(dot.contains("[UNMARKED]"));
        assert!(dot.contains("unnamed"));
    }
}
