//! A lightweight per-crate program model, built by brace-matching the
//! lexer's token stream.
//!
//! This is what turns the lint from a token scanner into a structure-aware
//! analysis: for every file it extracts **functions** (name + line span +
//! call sites), **lock acquisitions** (which lock, where, and how long the
//! guard lives), **blocking operations** (`recv`/`join`/`sleep`/wire IO),
//! **spawn sites** (thread name, `lint: thread:` marker, closure body) and
//! **channel constructions** (bounded/unbounded, capacity expression,
//! sender/receiver bindings, which spawn captures which endpoint). The
//! interprocedural rules PL006–PL010 and the `--graph` topology dump all
//! run over this model.
//!
//! Name resolution is deliberately *lite*: calls are resolved by bare
//! function name across the crate (same-named functions merge, which
//! over-approximates — safe for a lint), locks are identified by the last
//! path segment of their receiver (`self.inner.lock()` → `inner`), and
//! closures passed to `.spawn(` are attributed to the spawned thread, not
//! the enclosing function. Test regions (`#[cfg(test)]`) are excluded
//! from the model entirely.

use crate::lexer::SourceFile;

/// What kind of potentially-blocking operation a line performs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockKind {
    /// `.recv(` / `.recv_timeout(` — channel receive.
    Recv,
    /// `.join()` — thread join.
    Join,
    /// `thread::sleep` — timed block.
    Sleep,
    /// `write_to(` / `read_from(` — synchronous wire IO on a socket.
    Wire,
}

impl BlockKind {
    pub fn describe(self) -> &'static str {
        match self {
            BlockKind::Recv => "channel recv",
            BlockKind::Join => "thread join",
            BlockKind::Sleep => "sleep",
            BlockKind::Wire => "wire IO",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Blocking {
    pub kind: BlockKind,
    /// 1-based line.
    pub line: usize,
}

#[derive(Debug, Clone)]
pub struct Call {
    /// Bare callee name (`lock_inner`, `drive`, `close`, …).
    pub name: String,
    pub line: usize,
}

#[derive(Debug)]
pub struct Acquisition {
    /// Lock identity: last path segment of the receiver
    /// (`self.shared.lock()` → `shared`), or of the helper's argument
    /// (`lock_inner(&self.inner)` → `inner`).
    pub lock: String,
    pub line: usize,
    /// `Some` when the guard is `let`-bound and therefore outlives the
    /// statement; `None` for a temporary that dies on its own line.
    pub binding: Option<String>,
    /// Last line (inclusive) on which the guard is still live: the end of
    /// the enclosing block, an explicit `drop(binding)`, or `line` itself
    /// for a temporary.
    pub live_to: usize,
}

#[derive(Debug)]
pub struct Function {
    pub name: String,
    /// Index into `Model::files`.
    pub file: usize,
    /// 1-based line of the `fn` keyword.
    pub start: usize,
    /// 1-based line of the body's closing brace.
    pub end: usize,
    /// Declares a `-> …Guard` return: calling it acquires a lock that
    /// lives on in the caller (`lock_inner`-style helpers).
    pub returns_guard: bool,
    pub calls: Vec<Call>,
    pub acquisitions: Vec<Acquisition>,
    pub blocking: Vec<Blocking>,
}

#[derive(Debug)]
pub struct Spawn {
    pub file: usize,
    /// 1-based line of the `.spawn(` itself.
    pub line: usize,
    /// Thread name from the builder's `.name("…")`, read from raw text
    /// (format-string pieces survive: `net-tx-r{peer}`).
    pub thread_name: Option<String>,
    /// Carries a `lint: thread:` marker within the PL005 window.
    pub marked: bool,
    /// Enclosing function index, if any.
    pub func: Option<usize>,
    /// Last line (inclusive) of the `.spawn(…)` argument list — the
    /// closure body is attributed here, not to the enclosing function.
    pub body_end: usize,
    pub calls: Vec<Call>,
    pub blocking: Vec<Blocking>,
    /// Identifiers used inside the closure (for channel-endpoint capture
    /// resolution).
    idents: Vec<String>,
}

#[derive(Debug)]
pub struct Channel {
    pub file: usize,
    pub line: usize,
    /// `false` for `mpsc::channel()` (unbounded).
    pub bounded: bool,
    /// The capacity expression, verbatim, for bounded channels.
    pub capacity: Option<String>,
    /// Sender / receiver binding names; `None` when bound to `_`.
    pub tx: Option<String>,
    pub rx: Option<String>,
    pub func: Option<usize>,
    /// Spawn (index into `Model::spawns`) whose closure captures the
    /// sender / receiver, when one does.
    pub tx_spawn: Option<usize>,
    pub rx_spawn: Option<usize>,
}

#[derive(Debug, Default)]
pub struct Model {
    /// Relative paths, in scan order (sorted — the report order).
    pub files: Vec<String>,
    pub functions: Vec<Function>,
    pub spawns: Vec<Spawn>,
    pub channels: Vec<Channel>,
}

impl Model {
    pub fn build(files: &[(String, SourceFile)]) -> Model {
        let mut m = Model::default();
        let mut helper_calls: Vec<(usize, String, usize, usize)> = Vec::new();
        for (rel, sf) in files {
            let file_idx = m.files.len();
            m.files.push(rel.clone());
            scan_file(&mut m, file_idx, sf, &mut helper_calls);
        }
        // Spawns and channels get their enclosing function attached once
        // the whole function table exists.
        for si in 0..m.spawns.len() {
            m.spawns[si].func = m.enclosing_index(m.spawns[si].file, m.spawns[si].line);
        }
        for ci in 0..m.channels.len() {
            m.channels[ci].func = m.enclosing_index(m.channels[ci].file, m.channels[ci].line);
        }
        m.resolve_guard_helpers(files, helper_calls);
        m.resolve_channel_captures();
        m
    }

    /// Functions matching a bare name (same-named functions merge — the
    /// over-approximation the module docs call out).
    pub fn functions_named<'a>(&'a self, name: &str) -> impl Iterator<Item = &'a Function> {
        self.functions.iter().filter(move |f| f.name == name)
    }

    /// The function whose span contains `line` of `file`, innermost wins.
    pub fn enclosing_index(&self, file: usize, line: usize) -> Option<usize> {
        self.functions
            .iter()
            .enumerate()
            .filter(|(_, f)| f.file == file && f.start <= line && line <= f.end)
            .min_by_key(|(_, f)| f.end - f.start)
            .map(|(i, _)| i)
    }

    /// Second pass: a `let g = helper(&self.x)` call to a
    /// `-> …Guard`-returning helper is a lock acquisition of `x` in the
    /// *caller*. Needs the full function table, hence post-build.
    fn resolve_guard_helpers(
        &mut self,
        files: &[(String, SourceFile)],
        calls: Vec<(usize, String, usize, usize)>,
    ) {
        let guard_fns: Vec<String> = self
            .functions
            .iter()
            .filter(|f| f.returns_guard)
            .map(|f| f.name.clone())
            .collect();
        for (fn_idx, callee, file_idx, line) in calls {
            if !guard_fns.iter().any(|g| g == &callee) {
                continue;
            }
            let sf = &files[file_idx].1;
            let code = &sf.lines[line - 1].code;
            let Some(binding) = let_binding(code) else { continue };
            let Some(lock) = helper_lock_arg(code, &callee) else { continue };
            let fn_end = self.functions[fn_idx].end;
            // Approximation: a helper-acquired guard lives to an explicit
            // `drop(binding)` or to the end of the function (helper
            // acquisitions in this tree sit at function-body top level).
            let live_to = drop_line(sf, line - 1, fn_end, &binding).unwrap_or(fn_end);
            self.functions[fn_idx].acquisitions.push(Acquisition {
                lock,
                line,
                binding: Some(binding),
                live_to,
            });
        }
        for f in &mut self.functions {
            f.acquisitions.sort_by_key(|a| a.line);
        }
    }

    /// Match channel endpoint bindings against spawn-closure identifier
    /// sets, within the same enclosing function.
    fn resolve_channel_captures(&mut self) {
        for ch in &mut self.channels {
            for (si, sp) in self.spawns.iter().enumerate() {
                if sp.file != ch.file || sp.func != ch.func || sp.func.is_none() {
                    continue;
                }
                if let Some(tx) = &ch.tx {
                    if sp.idents.iter().any(|i| i == tx) {
                        ch.tx_spawn.get_or_insert(si);
                    }
                }
                if let Some(rx) = &ch.rx {
                    if sp.idents.iter().any(|i| i == rx) {
                        ch.rx_spawn.get_or_insert(si);
                    }
                }
            }
        }
    }
}

/// Per-file extraction. Guard-helper candidate calls are appended to
/// `helper_calls` as `(function index, callee, file index, line)` for the
/// post-build resolution pass.
fn scan_file(
    m: &mut Model,
    file_idx: usize,
    sf: &SourceFile,
    helper_calls: &mut Vec<(usize, String, usize, usize)>,
) {
    // Pass 1: spawn sites and their `( … )` argument spans, so closure
    // bodies can be attributed to the thread rather than the function.
    let spawn_spans = find_spawns(m, file_idx, sf);
    let in_spawn_body = |lineno: usize| {
        spawn_spans.iter().find(|&&(s, e, _)| lineno > s && lineno <= e).map(|&(_, _, si)| si)
    };
    let is_spawn_line =
        |lineno: usize| spawn_spans.iter().find(|&&(s, _, _)| s == lineno).map(|&(_, _, si)| si);

    // Pass 2: brace-matched function scan. `end_depth[i]` records the
    // brace depth after line `i`, for guard live-range computation.
    struct OpenFn {
        idx: usize,
        decl_depth: i64,
        opened: bool,
    }
    let mut stack: Vec<OpenFn> = Vec::new();
    let mut depth: i64 = 0;
    let mut end_depth = vec![0i64; sf.lines.len()];
    let mut owner_of = vec![usize::MAX; sf.lines.len()];

    for (i, line) in sf.lines.iter().enumerate() {
        let lineno = i + 1;
        if sf.in_test[i] {
            end_depth[i] = depth;
            continue;
        }
        let code = line.code.as_str();

        if let Some(name) = fn_decl_name(code) {
            m.functions.push(Function {
                name,
                file: file_idx,
                start: lineno,
                end: lineno,
                returns_guard: code.contains("Guard"),
                calls: Vec::new(),
                acquisitions: Vec::new(),
                blocking: Vec::new(),
            });
            stack.push(OpenFn { idx: m.functions.len() - 1, decl_depth: depth, opened: false });
        }
        if let Some(top) = stack.last() {
            owner_of[i] = top.idx;
        }

        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if let Some(top) = stack.last_mut() {
                        if !top.opened && depth == top.decl_depth + 1 {
                            top.opened = true;
                        }
                    }
                }
                '}' => {
                    depth -= 1;
                    if let Some(top) = stack.last() {
                        if top.opened && depth == top.decl_depth {
                            m.functions[top.idx].end = lineno;
                            stack.pop();
                        }
                    }
                }
                ';' => {
                    // A bodyless trait-method declaration: un-register it.
                    if let Some(top) = stack.last() {
                        if !top.opened && depth == top.decl_depth {
                            let idx = top.idx;
                            stack.pop();
                            m.functions.remove(idx);
                            let fallback = stack.last().map(|t| t.idx).unwrap_or(usize::MAX);
                            for f in owner_of.iter_mut() {
                                if *f == idx {
                                    *f = fallback;
                                } else if *f != usize::MAX && *f > idx {
                                    *f -= 1;
                                }
                            }
                            for f in stack.iter_mut() {
                                if f.idx > idx {
                                    f.idx -= 1;
                                }
                            }
                        }
                    }
                }
                _ => {}
            }
        }
        end_depth[i] = depth;
    }
    while let Some(top) = stack.pop() {
        m.functions[top.idx].end = sf.lines.len().max(m.functions[top.idx].start);
    }

    // Pass 3: feature collection, with complete spans and depths.
    for (i, line) in sf.lines.iter().enumerate() {
        let lineno = i + 1;
        if sf.in_test[i] {
            continue;
        }
        let code = line.code.as_str();
        let calls = collect_calls(code, lineno);
        let blocking = collect_blocking(code, lineno);

        if let Some(si) = in_spawn_body(lineno) {
            let sp = &mut m.spawns[si];
            sp.calls.extend(calls);
            sp.blocking.extend(blocking);
            sp.idents.extend(collect_idents(code));
            continue;
        }
        if let Some(si) = is_spawn_line(lineno) {
            // The spawn line itself: the closure head. Its identifiers
            // count as captures; its calls are the builder chain — noise
            // either way, so they are not attributed to the function.
            m.spawns[si].idents.extend(collect_idents(code));
            continue;
        }
        let fn_idx = owner_of[i];
        if fn_idx == usize::MAX {
            continue;
        }

        for c in &calls {
            helper_calls.push((fn_idx, c.name.clone(), file_idx, lineno));
        }
        m.functions[fn_idx].calls.extend(calls);
        m.functions[fn_idx].blocking.extend(blocking);

        if let Some((bounded, capacity)) = channel_on_line(code) {
            let (tx, rx) = tuple_bindings(code).unwrap_or((None, None));
            m.channels.push(Channel {
                file: file_idx,
                line: lineno,
                bounded,
                capacity,
                tx,
                rx,
                func: None,
                tx_spawn: None,
                rx_spawn: None,
            });
        }

        for lock in lock_receivers(code) {
            let binding = let_binding(code);
            let live_to = match &binding {
                Some(b) => {
                    let block = block_end(&end_depth, i, end_depth[i]);
                    drop_line(sf, i, block, b).unwrap_or(block)
                }
                None => lineno,
            };
            m.functions[fn_idx].acquisitions.push(Acquisition {
                lock,
                line: lineno,
                binding,
                live_to,
            });
        }
    }
}

/// Locate `.spawn(` sites, compute their argument spans, and register the
/// spawn records. Returns `(spawn_line, span_end_line, spawn_index)`.
fn find_spawns(m: &mut Model, file_idx: usize, sf: &SourceFile) -> Vec<(usize, usize, usize)> {
    let mut spans = Vec::new();
    for (i, line) in sf.lines.iter().enumerate() {
        if sf.in_test[i] {
            continue;
        }
        let code = line.code.as_str();
        let pos = match code
            .find(".spawn(")
            .map(|p| p + 1)
            .or_else(|| code.find("thread::spawn(").map(|p| p + "thread::".len()))
        {
            Some(p) => p,
            None => continue,
        };
        if code.contains("scope.spawn") || code.contains("s.spawn(") {
            continue; // scoped: the scope joins; not a topology node
        }
        let open = pos + "spawn".len();
        let body_end = balance_parens(sf, i, open);
        let name_hi = (body_end - 1).min(sf.lines.len().saturating_sub(1));
        let thread_name =
            (i.saturating_sub(6)..=name_hi).find_map(|j| name_literal(&sf.lines[j].raw));
        let marked =
            (i.saturating_sub(6)..=i).any(|j| sf.lines[j].comment.contains("lint: thread:"));
        m.spawns.push(Spawn {
            file: file_idx,
            line: i + 1,
            thread_name,
            marked,
            func: None,
            body_end,
            calls: Vec::new(),
            blocking: Vec::new(),
            idents: Vec::new(),
        });
        spans.push((i + 1, body_end, m.spawns.len() - 1));
    }
    spans
}

/// First line (1-based, inclusive) at or after `from` (0-based) where the
/// paren nesting opened at char `col` of line `from` closes.
pub(crate) fn balance_parens(sf: &SourceFile, from: usize, col: usize) -> usize {
    let mut depth = 0i64;
    for (i, line) in sf.lines.iter().enumerate().skip(from) {
        let start = if i == from { col } else { 0 };
        for c in line.code.chars().skip(start) {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        return i + 1;
                    }
                }
                _ => {}
            }
        }
    }
    sf.lines.len().max(from + 1)
}

/// `fn name` from a declaration line, if the line declares one.
fn fn_decl_name(code: &str) -> Option<String> {
    let mut search = 0;
    while let Some(p) = code[search..].find("fn ") {
        let at = search + p;
        let bounded = at == 0
            || !code[..at].chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_');
        if bounded {
            let name: String = code[at + 3..]
                .trim_start()
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        search = at + 3;
    }
    None
}

/// Bare callee names for every `ident(` on the line (macros and control
/// keywords excluded).
fn collect_calls(code: &str, line: usize) -> Vec<Call> {
    const KEYWORDS: [&str; 8] = ["if", "while", "for", "match", "return", "loop", "fn", "impl"];
    let chars: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    for (p, &c) in chars.iter().enumerate() {
        if c != '(' || p == 0 {
            continue;
        }
        let mut s = p;
        while s > 0 && (chars[s - 1].is_alphanumeric() || chars[s - 1] == '_') {
            s -= 1;
        }
        if s == p {
            continue;
        }
        if s > 0 && chars[s - 1] == '!' {
            continue; // macro
        }
        let name: String = chars[s..p].iter().collect();
        if KEYWORDS.contains(&name.as_str()) || name.chars().next().is_some_and(char::is_numeric) {
            continue;
        }
        out.push(Call { name, line });
    }
    out
}

fn collect_blocking(code: &str, line: usize) -> Vec<Blocking> {
    let mut out = Vec::new();
    if code.contains(".recv(") || code.contains(".recv_timeout(") {
        out.push(Blocking { kind: BlockKind::Recv, line });
    }
    if code.contains(".join()") {
        out.push(Blocking { kind: BlockKind::Join, line });
    }
    if code.contains("thread::sleep") {
        out.push(Blocking { kind: BlockKind::Sleep, line });
    }
    if code.contains("write_to(") || code.contains("read_from(") {
        out.push(Blocking { kind: BlockKind::Wire, line });
    }
    out
}

/// All identifiers on a line (capture resolution for spawn closures).
fn collect_idents(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in code.chars() {
        if c.is_alphanumeric() || c == '_' {
            cur.push(c);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Receiver identities for `.lock()` (and RwLock `.read()`/`.write()`)
/// acquisitions on this line.
fn lock_receivers(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    for pat in [".lock()", ".read()", ".write()"] {
        if pat != ".lock()" && !code.contains("RwLock") {
            // `.read()`/`.write()` are only lock acquisitions when the
            // line is visibly about an RwLock — IO traits share the
            // names. (No RwLock exists in the tree today; fixtures do.)
            continue;
        }
        let mut search = 0;
        while let Some(p) = code[search..].find(pat) {
            let at = search + p;
            if let Some(recv) = receiver_segment(&code[..at]) {
                out.push(recv);
            }
            search = at + pat.len();
        }
    }
    out
}

/// Last path segment of the receiver expression ending at `prefix`'s end:
/// `…self.shared` → `shared`.
fn receiver_segment(prefix: &str) -> Option<String> {
    let chars: Vec<char> = prefix.chars().collect();
    let mut s = chars.len();
    while s > 0 && (chars[s - 1].is_alphanumeric() || chars[s - 1] == '_' || chars[s - 1] == '.') {
        s -= 1;
    }
    let path: String = chars[s..].iter().collect();
    path.split('.').filter(|seg| !seg.is_empty()).next_back().map(str::to_string)
}

/// `let [mut] name = …` binding name, if the line is one.
fn let_binding(code: &str) -> Option<String> {
    let t = code.trim_start();
    let rest = t.strip_prefix("let ")?.trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let name: String = rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
    (!name.is_empty()).then_some(name)
}

/// `let (a, b) = …` tuple binding names; `_` maps to `None`.
fn tuple_bindings(code: &str) -> Option<(Option<String>, Option<String>)> {
    let t = code.trim_start();
    let rest = t.strip_prefix("let ")?.trim_start();
    let rest = rest.strip_prefix('(')?;
    let inner = &rest[..rest.find(')')?];
    let mut parts = inner.split(',');
    let clean = |s: &str| {
        let s = s.trim();
        let s = s.strip_prefix("mut ").unwrap_or(s).trim();
        let name: String = s.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
        (!name.is_empty() && name != "_").then_some(name)
    };
    let a = clean(parts.next()?);
    let b = clean(parts.next()?);
    Some((a, b))
}

/// Channel construction on this line: `Some((bounded, capacity))`.
///
/// Recognizes `mpsc::channel()` / `channel::<T>()` (unbounded),
/// `sync_channel(expr)` (bounded, capacity extracted) and bounded wrapper
/// constructors like `BucketTx::channel(expr)` (any `…::channel(` with a
/// non-empty argument list). Capacity expressions are line-local — every
/// construction in this tree fits one line, and the fixtures pin that.
fn channel_on_line(code: &str) -> Option<(bool, Option<String>)> {
    for (pat, sync) in [("sync_channel", true), ("channel", false)] {
        let mut search = 0;
        while let Some(p) = code[search..].find(pat) {
            let at = search + p;
            search = at + pat.len();
            let before_ok = at == 0
                || !code[..at].chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_');
            if !before_ok {
                continue;
            }
            let mut rest = &code[at + pat.len()..];
            if let Some(generic) = rest.strip_prefix("::<") {
                let Some(close) = generic.find('>') else { continue };
                rest = &generic[close + 1..];
            }
            let Some(args) = rest.strip_prefix('(') else { continue };
            let Some(close) = find_balanced_close(args) else { continue };
            let cap = args[..close].trim();
            if sync || !cap.is_empty() {
                return Some((true, Some(cap.to_string()).filter(|c| !c.is_empty())));
            }
            return Some((false, None));
        }
    }
    None
}

/// Index of the `)` closing the paren group whose contents start `s`.
fn find_balanced_close(s: &str) -> Option<usize> {
    let mut depth = 1i64;
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// For `let g = helper(&self.inner)…`: the last path segment of the
/// helper's first argument.
fn helper_lock_arg(code: &str, helper: &str) -> Option<String> {
    let p = code.find(&format!("{helper}("))?;
    let args = &code[p + helper.len() + 1..];
    let end = args.find([',', ')'])?;
    let first = args[..end].trim().trim_start_matches('&');
    first.split('.').filter(|s| !s.is_empty()).next_back().map(str::to_string)
}

/// `.name("…")` string literal from raw text (format pieces survive).
fn name_literal(raw: &str) -> Option<String> {
    let p = raw.find(".name(")?;
    let rest = &raw[p + ".name(".len()..];
    let lit = &rest[rest.find('"')? + 1..];
    Some(lit[..lit.find('"')?].to_string())
}

/// First `drop(binding)` after 0-based line `after`, up to 1-based line
/// `hi` inclusive, as a 1-based line.
fn drop_line(sf: &SourceFile, after: usize, hi: usize, binding: &str) -> Option<usize> {
    let needle = format!("drop({binding})");
    ((after + 1)..hi.min(sf.lines.len()))
        .find(|&j| sf.lines[j].code.contains(&needle))
        .map(|j| j + 1)
}

/// Last 1-based line of the block open at 0-based line `i` with end-depth
/// `d`: the first later line whose end depth drops below `d`.
fn block_end(end_depth: &[i64], i: usize, d: i64) -> usize {
    for (j, &ed) in end_depth.iter().enumerate().skip(i + 1) {
        if ed < d {
            return j + 1;
        }
    }
    end_depth.len()
}

/// Resolve a bare callee name from `from_file`'s point of view: functions
/// of the same name in the same file win (trait impls of the same method
/// name in *other* files are almost never the callee); only when the file
/// defines none does resolution widen to the whole crate.
pub fn callees(model: &Model, from_file: usize, name: &str) -> Vec<usize> {
    let mut same = Vec::new();
    let mut all = Vec::new();
    for (i, f) in model.functions.iter().enumerate() {
        if f.name == name {
            all.push(i);
            if f.file == from_file {
                same.push(i);
            }
        }
    }
    if same.is_empty() {
        all
    } else {
        same
    }
}

/// Transitive may-block analysis over the call graph (bare-name edges
/// with same-file preference — see [`callees`]). Returns, per function
/// index, the function-and-primitive that makes it blocking, if any.
pub fn may_block(model: &Model) -> Vec<Option<(String, BlockKind)>> {
    let n = model.functions.len();
    let mut out: Vec<Option<(String, BlockKind)>> = vec![None; n];
    for (i, f) in model.functions.iter().enumerate() {
        if let Some(b) = f.blocking.first() {
            out[i] = Some((f.name.clone(), b.kind));
        }
    }
    loop {
        let mut changed = false;
        for i in 0..n {
            if out[i].is_some() {
                continue;
            }
            let file = model.functions[i].file;
            let hit = model.functions[i]
                .calls
                .iter()
                .find_map(|c| callees(model, file, &c.name).into_iter().find_map(|j| out[j].clone()));
            if let Some(h) = hit {
                out[i] = Some(h);
                changed = true;
            }
        }
        if !changed {
            return out;
        }
    }
}

/// Transitive set of locks a call into function `idx` can acquire.
/// Guard-returning helpers are excluded: their acquisition surfaces in
/// the caller via `resolve_guard_helpers`, so counting their internals
/// would double it under the helper's private parameter name.
pub fn transitive_locks(model: &Model, idx: usize, seen: &mut Vec<usize>) -> Vec<String> {
    if seen.contains(&idx) {
        return Vec::new();
    }
    seen.push(idx);
    let f = &model.functions[idx];
    if f.returns_guard {
        return Vec::new();
    }
    let mut out = Vec::new();
    for a in &f.acquisitions {
        if !out.contains(&a.lock) {
            out.push(a.lock.clone());
        }
    }
    for c in &f.calls {
        for j in callees(model, f.file, &c.name) {
            for l in transitive_locks(model, j, seen) {
                if !out.contains(&l) {
                    out.push(l);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn build(src: &str) -> Model {
        Model::build(&[("m.rs".to_string(), lex(src))])
    }

    #[test]
    fn functions_are_brace_matched_with_spans() {
        let src = "fn a() {\n    let x = 1;\n}\n\npub fn b(v: u8) -> u8 {\n    v\n}\n";
        let m = build(src);
        let names: Vec<_> =
            m.functions.iter().map(|f| (f.name.as_str(), f.start, f.end)).collect();
        assert_eq!(names, vec![("a", 1, 3), ("b", 5, 7)]);
    }

    #[test]
    fn trait_method_declarations_without_bodies_are_skipped() {
        let src = "trait T {\n    fn sig(&self) -> u8;\n    fn has_body(&self) -> u8 { 1 }\n}\n";
        let m = build(src);
        let names: Vec<_> = m.functions.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["has_body"]);
    }

    #[test]
    fn lock_acquisitions_carry_identity_binding_and_live_range() {
        let src = "fn f(&self) {\n    let mut g = self.shared.lock().unwrap();\n    \
                   g.x += 1;\n    drop(g);\n    self.other();\n}\n";
        let m = build(src);
        let a = &m.functions[0].acquisitions[0];
        assert_eq!((a.lock.as_str(), a.line, a.live_to), ("shared", 2, 4));
        assert_eq!(a.binding.as_deref(), Some("g"));
    }

    #[test]
    fn temporary_guards_die_on_their_own_line() {
        let src = "fn f(&self) {\n    self.err.lock().unwrap().take();\n    self.rest();\n}\n";
        let m = build(src);
        let a = &m.functions[0].acquisitions[0];
        assert_eq!((a.lock.as_str(), a.line, a.live_to), ("err", 2, 2));
        assert_eq!(a.binding, None);
    }

    #[test]
    fn guards_die_at_the_end_of_their_block_not_the_function() {
        let src = "fn f(&self) {\n    if cond {\n        let g = self.a.lock().unwrap();\n        \
                   g.touch();\n    }\n    self.after();\n}\n";
        let m = build(src);
        let a = &m.functions[0].acquisitions[0];
        assert_eq!((a.line, a.live_to), (3, 5));
    }

    #[test]
    fn guard_returning_helpers_acquire_in_the_caller() {
        let src = "fn lock_inner(m: &Mutex<u8>) -> std::sync::MutexGuard<'_, u8> {\n    \
                   m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)\n}\n\
                   fn f(&self) {\n    let g = lock_inner(&self.inner);\n    use_it(&g);\n}\n";
        let m = build(src);
        let f = m.functions.iter().find(|f| f.name == "f").unwrap();
        let a = f.acquisitions.iter().find(|a| a.lock == "inner").unwrap();
        assert_eq!((a.line, a.live_to), (5, 7));
        // and the helper's own internals do not pollute transitive locks
        let fi = m.functions.iter().position(|f| f.name == "f").unwrap();
        assert!(transitive_locks(&m, fi, &mut Vec::new()).contains(&"inner".to_string()));
        assert!(!transitive_locks(&m, fi, &mut Vec::new()).contains(&"m".to_string()));
    }

    #[test]
    fn spawn_closures_are_attributed_to_the_thread_not_the_function() {
        let src = "fn start(rx: Receiver<u8>) {\n    \
                   // lint: thread: joined — Drop joins.\n    \
                   let j = thread::Builder::new()\n        .name(\"worker-1\".into())\n        \
                   .spawn(move || {\n            while let Ok(v) = rx.recv() {\n                \
                   handle(v);\n            }\n        })\n        .unwrap();\n}\n";
        let m = build(src);
        let f = m.functions.iter().find(|f| f.name == "start").unwrap();
        assert!(f.blocking.is_empty(), "closure recv must not leak into the function");
        let sp = &m.spawns[0];
        assert_eq!(sp.thread_name.as_deref(), Some("worker-1"));
        assert!(sp.marked);
        assert!(sp.blocking.iter().any(|b| b.kind == BlockKind::Recv));
        assert_eq!(sp.func, Some(0));
    }

    #[test]
    fn channels_record_kind_capacity_bindings_and_captures() {
        let src = "fn wire(workers: usize) {\n    \
                   let (tx, rx) = mpsc::sync_channel(DEPTH * workers);\n    \
                   let (utx, _) = mpsc::channel::<u8>();\n    \
                   // lint: thread: joined — close() joins.\n    \
                   let j = thread::Builder::new().name(\"rx-worker\".into())\n        \
                   .spawn(move || drain(rx)).unwrap();\n}\n";
        let m = build(src);
        assert_eq!(m.channels.len(), 2);
        let b = &m.channels[0];
        assert!(b.bounded);
        assert_eq!(b.capacity.as_deref(), Some("DEPTH * workers"));
        assert_eq!((b.tx.as_deref(), b.rx.as_deref()), (Some("tx"), Some("rx")));
        assert_eq!(b.rx_spawn, Some(0));
        let u = &m.channels[1];
        assert!(!u.bounded);
        assert_eq!((u.tx.as_deref(), u.rx.as_deref()), (Some("utx"), None));
    }

    #[test]
    fn may_block_propagates_through_the_call_graph() {
        let src = "fn leaf(rx: &Receiver<u8>) {\n    let v = rx.recv().unwrap();\n}\n\
                   fn mid(rx: &Receiver<u8>) {\n    leaf(rx);\n}\n\
                   fn top(rx: &Receiver<u8>) {\n    mid(rx);\n}\n\
                   fn pure() {\n    let x = 1 + 2;\n}\n";
        let m = build(src);
        let mb = may_block(&m);
        let by_name =
            |n: &str| m.functions.iter().position(|f| f.name == n).map(|i| mb[i].clone()).unwrap();
        assert_eq!(by_name("leaf").unwrap().1, BlockKind::Recv);
        assert!(by_name("top").is_some());
        assert!(by_name("pure").is_none());
    }

    #[test]
    fn transitive_locks_cross_function_boundaries() {
        let src = "fn inner_take(&self) {\n    let g = self.b.lock().unwrap();\n}\n\
                   fn outer(&self) {\n    let g = self.a.lock().unwrap();\n    \
                   self.inner_take();\n}\n";
        let m = build(src);
        let fi = m.functions.iter().position(|f| f.name == "outer").unwrap();
        let locks = transitive_locks(&m, fi, &mut Vec::new());
        assert!(locks.contains(&"a".to_string()) && locks.contains(&"b".to_string()));
    }
}
