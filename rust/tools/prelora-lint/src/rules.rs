//! The determinism rule set (PL001–PL005).
//!
//! Each rule is a per-line substring check over lexed code (comments
//! stripped, string contents blanked — see `lexer`), scoped to the paths
//! where the invariant is load-bearing. Suppressions are comment
//! annotations and must carry a reason:
//!
//! ```text
//! // lint: allow(PL004): documented invariant panic — <why it cannot fire>
//! // lint: thread: joined — <who joins this handle, and when>
//! ```
//!
//! An `allow` without a reason does not suppress; it is itself reported.
//! The full catalog with rationale lives in docs/static-analysis.md.

use crate::lexer::SourceFile;

pub struct Finding {
    pub rule: &'static str,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

/// How far above a flagged line a `lint: allow(...)` annotation may sit
/// (multi-line justification comments push the marker upward).
const ALLOW_WINDOW: usize = 3;
/// How far above a `.spawn(` line a `lint: thread:` marker may sit —
/// builder chains put the marker well above the `.spawn(` itself.
const THREAD_WINDOW: usize = 6;

/// Function-level telemetry sinks: a wall-clock read whose enclosing
/// function feeds one of these fields is measurement, not state.
const TELEMETRY_FIELDS: [&str; 3] = ["execute_seconds", "comm_wait_s", "compile_seconds"];

/// Directories (relative to `src/`) where replicas must agree bitwise.
const DETERMINISTIC_DIRS: [&str; 4] = ["dist", "dp", "pipeline", "runtime"];
/// PL002 is scoped tighter: float reductions only happen in these.
const REDUCE_DIRS: [&str; 3] = ["dist", "dp", "pipeline"];

pub const RULES: [(&str, &str); 5] = [
    (
        "PL001",
        "no HashMap/HashSet in deterministic paths (dist/, dp/, pipeline/, runtime/) — \
         iteration order varies per process; use BTreeMap/BTreeSet or sorted keys",
    ),
    (
        "PL002",
        "no unordered float reduction (.sum()/.fold()) in reduce/clip paths — float \
         addition is non-associative; use the explicit in-order helpers",
    ),
    (
        "PL003",
        "no wall-clock (Instant/SystemTime) outside telemetry-only functions — time must \
         never flow into bitwise-compared state",
    ),
    (
        "PL004",
        "no unwrap()/expect() in non-test library code under dist/, dp/, pipeline/, \
         checkpoint.rs — return Result, or annotate the documented invariant",
    ),
    (
        "PL005",
        "every spawned thread needs a `lint: thread:` marker naming who joins it (or its \
         detach story); scoped threads are exempt",
    ),
];

pub fn check_file(rel: &str, file: &SourceFile) -> Vec<Finding> {
    let ann: Vec<Annotations> = file.lines.iter().map(|l| parse_annotations(&l.comment)).collect();
    let mut out = Vec::new();

    for (idx, line) in file.lines.iter().enumerate() {
        // Reasonless allows are findings wherever they appear: a bare
        // suppression defeats the audit trail the annotation exists for.
        for id in &ann[idx].bare_allows {
            out.push(Finding {
                rule: "PL000",
                line: idx + 1,
                message: format!("allow({id}) without a reason — write `allow({id}): <why>`"),
            });
        }
        if file.in_test[idx] {
            continue;
        }
        let code = line.code.as_str();

        if in_dirs(rel, &DETERMINISTIC_DIRS)
            && (code.contains("HashMap") || code.contains("HashSet"))
            && !allowed(&ann, idx, "PL001")
        {
            out.push(finding("PL001", idx, "hash-ordered container in a deterministic path"));
        }

        if in_dirs(rel, &REDUCE_DIRS) && !allowed(&ann, idx, "PL002") {
            if code.contains(".sum::<f32") || code.contains(".sum::<f64") {
                out.push(finding("PL002", idx, "unordered float .sum() — use sq_sum_in_order"));
            } else if (code.contains(".sum()") || code.contains(".fold("))
                && !(code.contains("len") || code.contains("count") || code.contains("usize"))
            {
                out.push(finding(
                    "PL002",
                    idx,
                    "possibly-float reduction without an explicit order (annotate if integral)",
                ));
            }
        }

        if (in_dirs(rel, &DETERMINISTIC_DIRS) || rel == "checkpoint.rs")
            && (code.contains("Instant::now") || code.contains("SystemTime::now"))
            && !enclosing_fn_mentions(file, idx, &TELEMETRY_FIELDS)
            && !allowed(&ann, idx, "PL003")
        {
            out.push(finding(
                "PL003",
                idx,
                "wall-clock read in a function that is not a telemetry sink",
            ));
        }

        if (in_dirs(rel, &REDUCE_DIRS) || rel == "checkpoint.rs")
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && !allowed(&ann, idx, "PL004")
        {
            out.push(finding("PL004", idx, "unwrap/expect in library code"));
        }

        if (code.contains(".spawn(") || code.contains("thread::spawn"))
            && !code.contains("scope.spawn")
            && !thread_marked(&ann, idx)
            && !allowed(&ann, idx, "PL005")
        {
            out.push(finding(
                "PL005",
                idx,
                "spawned thread without a `lint: thread:` join/detach marker",
            ));
        }
    }
    out
}

fn finding(rule: &'static str, idx: usize, message: &str) -> Finding {
    Finding { rule, line: idx + 1, message: message.to_string() }
}

fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| rel.starts_with(d) && rel[d.len()..].starts_with('/'))
}

struct Annotations {
    /// Rule ids with a non-empty reason — these suppress.
    allows: Vec<String>,
    /// Rule ids written without a reason — these are findings.
    bare_allows: Vec<String>,
    thread_marker: bool,
}

fn parse_annotations(comment: &str) -> Annotations {
    let mut allows = Vec::new();
    let mut bare_allows = Vec::new();
    let mut rest = comment;
    while let Some(p) = rest.find("lint: allow(") {
        rest = &rest[p + "lint: allow(".len()..];
        let Some(close) = rest.find(')') else { break };
        let id = rest[..close].trim().to_string();
        rest = &rest[close + 1..];
        let has_reason = rest
            .strip_prefix(':')
            .is_some_and(|r| !r.trim_start().is_empty() && !r.trim_start().starts_with("lint:"));
        if id.is_empty() {
            continue;
        }
        if has_reason {
            allows.push(id);
        } else {
            bare_allows.push(id);
        }
    }
    Annotations { allows, bare_allows, thread_marker: comment.contains("lint: thread:") }
}

/// An allow on the flagged line or within `ALLOW_WINDOW` lines above it.
fn allowed(ann: &[Annotations], idx: usize, rule: &str) -> bool {
    let lo = idx.saturating_sub(ALLOW_WINDOW);
    ann[lo..=idx].iter().any(|a| a.allows.iter().any(|r| r == rule))
}

fn thread_marked(ann: &[Annotations], idx: usize) -> bool {
    let lo = idx.saturating_sub(THREAD_WINDOW);
    ann[lo..=idx].iter().any(|a| a.thread_marker)
}

/// True when any line of the function enclosing `idx` mentions one of
/// `needles`. The span is approximated as [nearest `fn ` at-or-above,
/// next `fn ` below) — good enough because telemetry fields are assigned
/// in the same function body that reads the clock.
fn enclosing_fn_mentions(file: &SourceFile, idx: usize, needles: &[&str]) -> bool {
    let is_fn = |i: usize| file.lines[i].code.contains("fn ");
    let start = (0..=idx).rev().find(|&i| is_fn(i)).unwrap_or(0);
    let end = ((idx + 1)..file.lines.len()).find(|&i| is_fn(i)).unwrap_or(file.lines.len());
    file.lines[start..end]
        .iter()
        .any(|l| needles.iter().any(|n| l.code.contains(n)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn run(rel: &str, src: &str) -> Vec<(String, usize)> {
        check_file(rel, &lex(src))
            .into_iter()
            .map(|f| (f.rule.to_string(), f.line))
            .collect()
    }

    #[test]
    fn pl001_flags_hash_containers_only_in_scope() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(run("dp/engine.rs", src), vec![("PL001".into(), 1)]);
        assert_eq!(run("model.rs", src), vec![]);
        // prose and strings never match
        let prose = "// HashMap is banned here\nlet m = \"HashMap\";\n";
        assert_eq!(run("dp/engine.rs", prose), vec![]);
    }

    #[test]
    fn pl001_allow_with_reason_suppresses_within_window() {
        let src = "// lint: allow(PL001): single-key scratch map, never iterated\n\
                   // (continued justification)\n\
                   use std::collections::HashMap;\n";
        assert_eq!(run("dist/zero3.rs", src), vec![]);
    }

    #[test]
    fn bare_allow_is_reported_and_does_not_suppress() {
        let src = "// lint: allow(PL001)\nuse std::collections::HashMap;\n";
        let got = run("dp/engine.rs", src);
        assert_eq!(got, vec![("PL000".into(), 1), ("PL001".into(), 2)]);
    }

    #[test]
    fn pl002_flags_float_sums_but_not_length_arithmetic() {
        assert_eq!(
            run("dp/engine.rs", "let s = xs.iter().sum::<f32>();\n"),
            vec![("PL002".into(), 1)]
        );
        assert_eq!(run("dp/engine.rs", "let n: usize = xs.iter().map(Vec::len).sum();\n"), vec![]);
        // runtime/ is outside the reduce scope
        assert_eq!(run("runtime/client.rs", "let s = xs.iter().sum::<f32>();\n"), vec![]);
    }

    #[test]
    fn pl003_permits_telemetry_sinks_only() {
        let sink = "fn run(&self) {\n    let t0 = Instant::now();\n    \
                    self.execute_seconds.set(t0.elapsed().as_secs_f64());\n}\n";
        assert_eq!(run("runtime/executable.rs", sink), vec![]);
        let state = "fn seed(&self) -> u64 {\n    Instant::now().elapsed().as_nanos() as u64\n}\n";
        assert_eq!(run("dp/engine.rs", state), vec![("PL003".into(), 2)]);
    }

    #[test]
    fn pl004_skips_tests_and_honors_annotations() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n\
                   #[cfg(test)]\nmod tests {\n    fn t() { None::<u8>.unwrap(); }\n}\n";
        assert_eq!(run("checkpoint.rs", src), vec![("PL004".into(), 1)]);
        let annotated = "// lint: allow(PL004): documented invariant — x checked by caller\n\
                         fn f(x: Option<u8>) -> u8 { x.expect(\"checked\") }\n";
        assert_eq!(run("dist/model.rs", annotated), vec![]);
        // unwrap_or_else is not unwrap
        assert_eq!(run("dp/engine.rs", "let v = x.unwrap_or_else(Vec::new);\n"), vec![]);
    }

    #[test]
    fn pl005_requires_a_marker_within_the_window() {
        let bare = "let j = std::thread::Builder::new()\n    .name(\"w\".into())\n    \
                    .spawn(move || {})?;\n";
        assert_eq!(run("model.rs", bare), vec![("PL005".into(), 3)]);
        let marked = "// lint: thread: joined — Drop joins the handle.\n\
                      let j = std::thread::Builder::new()\n    .name(\"w\".into())\n    \
                      .spawn(move || {})?;\n";
        assert_eq!(run("model.rs", marked), vec![]);
        assert_eq!(run("model.rs", "scope.spawn(|| {});\n"), vec![]);
    }
}
