//! The determinism and concurrency rule set (PL001–PL010).
//!
//! PL001–PL005 are per-line substring checks over lexed code (comments
//! stripped, string contents blanked — see `lexer`), scoped to the paths
//! where the invariant is load-bearing. PL006–PL010 are crate-wide rules
//! over the program model built in `model` (functions, lock
//! acquisitions, spawns, channels, call graph) — see [`check_crate`].
//! Suppressions are comment annotations and must carry a reason:
//!
//! ```text
//! // lint: allow(PL004): documented invariant panic — <why it cannot fire>
//! // lint: thread: joined — <who joins this handle, and when>
//! ```
//!
//! An `allow` without a reason does not suppress; it is itself reported.
//! The full catalog with rationale lives in docs/static-analysis.md.

use crate::lexer::SourceFile;
use crate::model::{self, Model};

pub struct Finding {
    pub rule: &'static str,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

/// How far above a flagged line a `lint: allow(...)` annotation may sit
/// (multi-line justification comments push the marker upward).
const ALLOW_WINDOW: usize = 3;
/// How far above a `.spawn(` line a `lint: thread:` marker may sit —
/// builder chains put the marker well above the `.spawn(` itself.
const THREAD_WINDOW: usize = 6;

/// Function-level telemetry sinks: a wall-clock read whose enclosing
/// function feeds one of these fields is measurement, not state.
const TELEMETRY_FIELDS: [&str; 3] = ["execute_seconds", "comm_wait_s", "compile_seconds"];

/// Directories (relative to `src/`) where replicas must agree bitwise.
const DETERMINISTIC_DIRS: [&str; 4] = ["dist", "dp", "pipeline", "runtime"];
/// PL002 is scoped tighter: float reductions only happen in these.
const REDUCE_DIRS: [&str; 3] = ["dist", "dp", "pipeline"];

pub const RULES: [(&str, &str); 10] = [
    (
        "PL001",
        "no HashMap/HashSet in deterministic paths (dist/, dp/, pipeline/, runtime/) — \
         iteration order varies per process; use BTreeMap/BTreeSet or sorted keys",
    ),
    (
        "PL002",
        "no unordered float reduction (.sum()/.fold()) in reduce/clip paths — float \
         addition is non-associative; use the explicit in-order helpers",
    ),
    (
        "PL003",
        "no wall-clock (Instant/SystemTime) outside telemetry-only functions — time must \
         never flow into bitwise-compared state",
    ),
    (
        "PL004",
        "no unwrap()/expect() in non-test library code under dist/, dp/, pipeline/, \
         checkpoint.rs — return Result, or annotate the documented invariant",
    ),
    (
        "PL005",
        "every spawned thread needs a `lint: thread:` marker naming who joins it (or its \
         detach story); scoped threads are exempt",
    ),
    (
        "PL006",
        "one global lock-acquisition order — two functions nesting the same pair of locks \
         in opposite orders is a deadlock in waiting; both witness paths are printed",
    ),
    (
        "PL007",
        "no blocking call (recv/join/sleep/wire IO, or taking another lock) while a lock \
         guard is live, in dist/, dp/, pipeline/",
    ),
    (
        "PL008",
        "channel topology audit: every sender has a named owning receiver, unbounded \
         channel() is banned on hot paths, sync_channel capacities are named constants, \
         and drained receivers belong to marker-carrying (PL005) threads",
    ),
    (
        "PL009",
        "every error constructed on the wire path (dist/net/) must interpolate at least \
         one of rank/peer/epoch/step/seq — context-free errors are undebuggable at 64 ranks",
    ),
    (
        "PL010",
        "fault-catalog closure: every FaultKind variant needs an injection consult site \
         in rust/src and a matching cell in rust/tests/adversity.rs",
    ),
];

pub fn check_file(rel: &str, file: &SourceFile) -> Vec<Finding> {
    let ann: Vec<Annotations> = file.lines.iter().map(|l| parse_annotations(&l.comment)).collect();
    let mut out = Vec::new();

    for (idx, line) in file.lines.iter().enumerate() {
        // Reasonless allows are findings wherever they appear: a bare
        // suppression defeats the audit trail the annotation exists for.
        for id in &ann[idx].bare_allows {
            out.push(Finding {
                rule: "PL000",
                line: idx + 1,
                message: format!("allow({id}) without a reason — write `allow({id}): <why>`"),
            });
        }
        if file.in_test[idx] {
            continue;
        }
        let code = line.code.as_str();

        if in_dirs(rel, &DETERMINISTIC_DIRS)
            && (code.contains("HashMap") || code.contains("HashSet"))
            && !allowed(&ann, idx, "PL001")
        {
            out.push(finding("PL001", idx, "hash-ordered container in a deterministic path"));
        }

        if in_dirs(rel, &REDUCE_DIRS) && !allowed(&ann, idx, "PL002") {
            if code.contains(".sum::<f32") || code.contains(".sum::<f64") {
                out.push(finding("PL002", idx, "unordered float .sum() — use sq_sum_in_order"));
            } else if (code.contains(".sum()") || code.contains(".fold("))
                && !(code.contains("len") || code.contains("count") || code.contains("usize"))
            {
                out.push(finding(
                    "PL002",
                    idx,
                    "possibly-float reduction without an explicit order (annotate if integral)",
                ));
            }
        }

        if (in_dirs(rel, &DETERMINISTIC_DIRS) || rel == "checkpoint.rs")
            && (code.contains("Instant::now") || code.contains("SystemTime::now"))
            && !enclosing_fn_mentions(file, idx, &TELEMETRY_FIELDS)
            && !allowed(&ann, idx, "PL003")
        {
            out.push(finding(
                "PL003",
                idx,
                "wall-clock read in a function that is not a telemetry sink",
            ));
        }

        // faults.rs runs on worker/wire threads; dist/net/frame.rs is
        // already covered by the dist/ prefix.
        if (in_dirs(rel, &REDUCE_DIRS) || rel == "checkpoint.rs" || rel == "faults.rs")
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && !allowed(&ann, idx, "PL004")
        {
            out.push(finding("PL004", idx, "unwrap/expect in library code"));
        }

        if (code.contains(".spawn(") || code.contains("thread::spawn"))
            && !code.contains("scope.spawn")
            && !thread_marked(&ann, idx)
            && !allowed(&ann, idx, "PL005")
        {
            out.push(finding(
                "PL005",
                idx,
                "spawned thread without a `lint: thread:` join/detach marker",
            ));
        }
    }
    out
}

fn finding(rule: &'static str, idx: usize, message: &str) -> Finding {
    Finding { rule, line: idx + 1, message: message.to_string() }
}

fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| rel.starts_with(d) && rel[d.len()..].starts_with('/'))
}

struct Annotations {
    /// Rule ids with a non-empty reason — these suppress.
    allows: Vec<String>,
    /// Rule ids written without a reason — these are findings.
    bare_allows: Vec<String>,
    thread_marker: bool,
}

fn parse_annotations(comment: &str) -> Annotations {
    let mut allows = Vec::new();
    let mut bare_allows = Vec::new();
    let mut rest = comment;
    while let Some(p) = rest.find("lint: allow(") {
        rest = &rest[p + "lint: allow(".len()..];
        let Some(close) = rest.find(')') else { break };
        let id = rest[..close].trim().to_string();
        rest = &rest[close + 1..];
        let has_reason = rest
            .strip_prefix(':')
            .is_some_and(|r| !r.trim_start().is_empty() && !r.trim_start().starts_with("lint:"));
        if id.is_empty() {
            continue;
        }
        if has_reason {
            allows.push(id);
        } else {
            bare_allows.push(id);
        }
    }
    Annotations { allows, bare_allows, thread_marker: comment.contains("lint: thread:") }
}

/// An allow on the flagged line or within `ALLOW_WINDOW` lines above it.
fn allowed(ann: &[Annotations], idx: usize, rule: &str) -> bool {
    let lo = idx.saturating_sub(ALLOW_WINDOW);
    ann[lo..=idx].iter().any(|a| a.allows.iter().any(|r| r == rule))
}

fn thread_marked(ann: &[Annotations], idx: usize) -> bool {
    let lo = idx.saturating_sub(THREAD_WINDOW);
    ann[lo..=idx].iter().any(|a| a.thread_marker)
}

// ---------------------------------------------------------------------------
// Crate-wide rules (PL006–PL010) over the program model.
// ---------------------------------------------------------------------------

/// Tokens that count as wire-path error context for PL009. Matched
/// against raw line text so `{rank}` interpolations inside format
/// strings are seen.
const WIRE_CONTEXT_TOKENS: [&str; 5] = ["rank", "peer", "epoch", "step", "seq"];
/// Error-construction triggers for PL009. `.context(` never matches a
/// `.with_context(` call: the preceding character there is `_`, not `.`.
const ERROR_TRIGGERS: [&str; 6] =
    ["bail!(", "ensure!(", "anyhow!(", "format_err!(", ".context(", ".with_context("];
/// Functions in faults.rs that merely *spell* variants (parse/print) —
/// appearing there is not an injection consult site for PL010.
const FAULT_PARSER_FNS: [&str; 5] = ["name", "parse", "parse_entry", "entry_spec", "to_spec"];

/// Run PL006–PL010 across the whole crate. `files` must be the same
/// slice (same order) the `Model` was built from; `adversity` is the
/// text of `tests/adversity.rs` when present. Returns `(file_index,
/// finding)` pairs sorted by (file, line, rule).
pub fn check_crate(
    files: &[(String, SourceFile)],
    model: &Model,
    adversity: Option<&str>,
) -> Vec<(usize, Finding)> {
    let ann: Vec<Vec<Annotations>> = files
        .iter()
        .map(|(_, sf)| sf.lines.iter().map(|l| parse_annotations(&l.comment)).collect())
        .collect();
    let mut out = Vec::new();
    pl006_lock_order(model, &ann, &mut out);
    pl007_blocking_under_lock(model, &ann, &mut out);
    pl008_channel_topology(model, &ann, &mut out);
    pl009_wire_error_context(files, &ann, &mut out);
    pl010_fault_catalog(files, model, adversity, &ann, &mut out);
    out.sort_by(|a, b| (a.0, a.1.line, a.1.rule).cmp(&(b.0, b.1.line, b.1.rule)));
    out
}

/// Push unless a reasoned `allow(rule)` sits within the window above.
fn push_crate(
    ann: &[Vec<Annotations>],
    out: &mut Vec<(usize, Finding)>,
    file: usize,
    rule: &'static str,
    line: usize,
    message: String,
) {
    if !allowed(&ann[file], line - 1, rule) {
        out.push((file, Finding { rule, line, message }));
    }
}

struct OrderWitness {
    file: usize,
    line: usize,
    detail: String,
}

/// PL006 — collect every ordered pair (outer, inner) witnessed anywhere:
/// directly nested acquisitions, or a call made under a live guard into a
/// function that transitively acquires. Any pair witnessed in both
/// directions is a deadlock in waiting; report it once, anchored at the
/// lexically-first direction's witness, printing both paths.
fn pl006_lock_order(model: &Model, ann: &[Vec<Annotations>], out: &mut Vec<(usize, Finding)>) {
    let mut pairs: Vec<((String, String), OrderWitness)> = Vec::new();
    let mut record = |pairs: &mut Vec<((String, String), OrderWitness)>,
                      outer: &str,
                      inner: &str,
                      w: OrderWitness| {
        let key = (outer.to_string(), inner.to_string());
        if !pairs.iter().any(|(k, _)| *k == key) {
            pairs.push((key, w));
        }
    };
    for f in &model.functions {
        for a in &f.acquisitions {
            for b in &f.acquisitions {
                if b.line > a.line && b.line <= a.live_to && b.lock != a.lock {
                    let w = OrderWitness {
                        file: f.file,
                        line: b.line,
                        detail: format!(
                            "`{}` takes `{}` (line {}) then `{}` (line {})",
                            f.name, a.lock, a.line, b.lock, b.line
                        ),
                    };
                    record(&mut pairs, &a.lock, &b.lock, w);
                }
            }
            for c in &f.calls {
                if c.line <= a.line || c.line > a.live_to {
                    continue;
                }
                for j in model::callees(model, f.file, &c.name) {
                    for l in model::transitive_locks(model, j, &mut Vec::new()) {
                        if l == a.lock {
                            continue;
                        }
                        let w = OrderWitness {
                            file: f.file,
                            line: c.line,
                            detail: format!(
                                "`{}` holds `{}` (line {}) across a call to `{}` (line {}), \
                                 which acquires `{}`",
                                f.name, a.lock, a.line, c.name, c.line, l
                            ),
                        };
                        record(&mut pairs, &a.lock, &l, w);
                    }
                }
            }
        }
    }
    for (key, w) in &pairs {
        if key.0 >= key.1 {
            continue;
        }
        let rev = (key.1.clone(), key.0.clone());
        if let Some((_, wr)) = pairs.iter().find(|(k, _)| *k == rev) {
            push_crate(
                ann,
                out,
                w.file,
                "PL006",
                w.line,
                format!(
                    "inconsistent lock order on `{}`/`{}`: {} [src/{}], but {} [src/{}]",
                    key.0, key.1, w.detail, model.files[w.file], wr.detail, model.files[wr.file]
                ),
            );
        }
    }
}

/// PL007 — inside dist/, dp/, pipeline/: while a guard is live, flag
/// direct blocking primitives, nested lock acquisitions, and calls that
/// (transitively, same-file-preferring resolution) block or acquire.
fn pl007_blocking_under_lock(
    model: &Model,
    ann: &[Vec<Annotations>],
    out: &mut Vec<(usize, Finding)>,
) {
    let mb = model::may_block(model);
    for f in &model.functions {
        if !in_dirs(&model.files[f.file], &REDUCE_DIRS) {
            continue;
        }
        for a in &f.acquisitions {
            for b in &f.blocking {
                if b.line > a.line && b.line <= a.live_to {
                    push_crate(
                        ann,
                        out,
                        f.file,
                        "PL007",
                        b.line,
                        format!(
                            "{} in `{}` while the `{}` guard (line {}) is live",
                            b.kind.describe(),
                            f.name,
                            a.lock,
                            a.line
                        ),
                    );
                }
            }
            for b in &f.acquisitions {
                if b.line > a.line && b.line <= a.live_to {
                    push_crate(
                        ann,
                        out,
                        f.file,
                        "PL007",
                        b.line,
                        format!(
                            "`{}` acquires `{}` while the `{}` guard (line {}) is live — \
                             nested locking blocks under contention",
                            f.name, b.lock, a.lock, a.line
                        ),
                    );
                }
            }
            for c in &f.calls {
                if c.line <= a.line || c.line > a.live_to {
                    continue;
                }
                let resolved = model::callees(model, f.file, &c.name);
                if let Some((via, kind)) = resolved.iter().find_map(|&j| mb[j].clone()) {
                    push_crate(
                        ann,
                        out,
                        f.file,
                        "PL007",
                        c.line,
                        format!(
                            "`{}` calls `{}` — which can block ({} in `{}`) — while the `{}` \
                             guard (line {}) is live",
                            f.name,
                            c.name,
                            kind.describe(),
                            via,
                            a.lock,
                            a.line
                        ),
                    );
                } else {
                    let mut locks: Vec<String> = Vec::new();
                    for &j in &resolved {
                        for l in model::transitive_locks(model, j, &mut Vec::new()) {
                            if !locks.contains(&l) {
                                locks.push(l);
                            }
                        }
                    }
                    if !locks.is_empty() {
                        push_crate(
                            ann,
                            out,
                            f.file,
                            "PL007",
                            c.line,
                            format!(
                                "`{}` calls `{}` — which acquires `{}` — while the `{}` guard \
                                 (line {}) is live",
                                f.name,
                                c.name,
                                locks.join("`, `"),
                                a.lock,
                                a.line
                            ),
                        );
                    }
                }
            }
        }
    }
}

/// PL008 — channel topology: orphaned senders anywhere; unbounded or
/// magic-capacity channels on the hot paths; receivers drained by
/// marker-less threads.
fn pl008_channel_topology(
    model: &Model,
    ann: &[Vec<Annotations>],
    out: &mut Vec<(usize, Finding)>,
) {
    for ch in &model.channels {
        let hot = in_dirs(&model.files[ch.file], &REDUCE_DIRS);
        if ch.tx.is_some() && ch.rx.is_none() {
            push_crate(
                ann,
                out,
                ch.file,
                "PL008",
                ch.line,
                format!(
                    "channel sender `{}` has no named owning receiver — bind the receiving \
                     end and route it",
                    ch.tx.as_deref().unwrap_or("_")
                ),
            );
        }
        if hot && !ch.bounded {
            push_crate(
                ann,
                out,
                ch.file,
                "PL008",
                ch.line,
                "unbounded channel() on a hot path — use sync_channel with a named-constant \
                 bound"
                    .to_string(),
            );
        }
        if hot && ch.bounded {
            if let Some(cap) = &ch.capacity {
                if let Some(n) = magic_number(cap) {
                    push_crate(
                        ann,
                        out,
                        ch.file,
                        "PL008",
                        ch.line,
                        format!(
                            "sync_channel capacity `{cap}` hard-codes {n} — name the bound as \
                             a constant"
                        ),
                    );
                }
            }
        }
        if let Some(rsi) = ch.rx_spawn {
            let sp = &model.spawns[rsi];
            if !sp.marked {
                push_crate(
                    ann,
                    out,
                    ch.file,
                    "PL008",
                    ch.line,
                    format!(
                        "receiver `{}` is drained by the thread spawned at line {}, which has \
                         no `lint: thread:` marker",
                        ch.rx.as_deref().unwrap_or("_"),
                        sp.line
                    ),
                );
            }
        }
    }
}

/// First integer literal > 1 in a capacity expression. 0/1 floors
/// (`depth.max(1)`) are structural, not tuning constants; digits inside
/// identifiers don't count.
fn magic_number(expr: &str) -> Option<u64> {
    let bytes = expr.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i].is_ascii_digit() {
            let start = i;
            while i < bytes.len() && (bytes[i].is_ascii_digit() || bytes[i] == b'_') {
                i += 1;
            }
            let prev_ident = start > 0
                && (bytes[start - 1].is_ascii_alphanumeric() || bytes[start - 1] == b'_');
            if !prev_ident {
                if let Ok(v) = expr[start..i].replace('_', "").parse::<u64>() {
                    if v > 1 {
                        return Some(v);
                    }
                }
            }
        } else {
            i += 1;
        }
    }
    None
}

/// PL009 — every error constructed under dist/net/ must carry at least
/// one of rank/peer/epoch/step/seq somewhere in its argument span
/// (paren-balanced from the trigger, so multi-line `ensure!` bodies
/// count).
fn pl009_wire_error_context(
    files: &[(String, SourceFile)],
    ann: &[Vec<Annotations>],
    out: &mut Vec<(usize, Finding)>,
) {
    for (fi, (rel, sf)) in files.iter().enumerate() {
        if !rel.starts_with("dist/net") {
            continue;
        }
        for i in 0..sf.lines.len() {
            if sf.in_test[i] {
                continue;
            }
            let code = sf.lines[i].code.as_str();
            let Some((trigger, pos)) = ERROR_TRIGGERS
                .iter()
                .filter_map(|t| code.find(t).map(|p| (*t, p)))
                .min_by_key(|&(_, p)| p)
            else {
                continue;
            };
            let open = pos + trigger.len() - 1;
            let end = model::balance_parens(sf, i, open); // 1-based inclusive last line
            let has_context = (i..end.max(i + 1)).any(|j| {
                sf.lines
                    .get(j)
                    .is_some_and(|l| WIRE_CONTEXT_TOKENS.iter().any(|t| l.raw.contains(t)))
            });
            if !has_context {
                push_crate(
                    ann,
                    out,
                    fi,
                    "PL009",
                    i + 1,
                    format!(
                        "error constructed on the wire path without rank/peer/epoch/step/seq \
                         context ({})",
                        trigger.trim_end_matches('(')
                    ),
                );
            }
        }
    }
}

/// PL010 — fault-catalog closure. Variants come from `enum FaultKind` in
/// faults.rs; the canonical token for each comes from the `FaultKind::V
/// => "tok"` arms of `fn name()`. A consult site is any word-bounded
/// `FaultKind::V` in non-test code outside the enum itself and outside
/// the parse/print helpers; a matrix cell is the token appearing in
/// tests/adversity.rs.
fn pl010_fault_catalog(
    files: &[(String, SourceFile)],
    model: &Model,
    adversity: Option<&str>,
    ann: &[Vec<Annotations>],
    out: &mut Vec<(usize, Finding)>,
) {
    let Some(fi) = files.iter().position(|(r, _)| r == "faults.rs") else {
        return;
    };
    let sf = &files[fi].1;
    let Some(start) = sf.lines.iter().position(|l| l.code.contains("enum FaultKind")) else {
        return;
    };
    let mut depth = 0i64;
    let mut opened = false;
    let mut end = start;
    'outer: for j in start..sf.lines.len() {
        for c in sf.lines[j].code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth -= 1;
                    if opened && depth == 0 {
                        end = j;
                        break 'outer;
                    }
                }
                _ => {}
            }
        }
    }

    let mut variants: Vec<(String, usize)> = Vec::new();
    for j in (start + 1)..end {
        let t = sf.lines[j].code.trim();
        let name: String = t.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
        if !name.is_empty() && name.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
            variants.push((name, j));
        }
    }

    // Spans (0-based, inclusive) that never count as consult sites.
    let model_fi = model.files.iter().position(|r| r == "faults.rs");
    let mut excluded: Vec<(usize, usize)> = vec![(start, end)];
    let mut name_span: Option<(usize, usize)> = None;
    if let Some(mfi) = model_fi {
        for f in &model.functions {
            if f.file == mfi && FAULT_PARSER_FNS.contains(&f.name.as_str()) {
                excluded.push((f.start - 1, f.end - 1));
                if f.name == "name" {
                    name_span = Some((f.start - 1, f.end - 1));
                }
            }
        }
    }

    // Canonical token: the string after `FaultKind::V … => "` inside
    // fn name() (fall back to the whole file when the span is unknown).
    let (tlo, thi) = name_span.unwrap_or((0, sf.lines.len().saturating_sub(1)));
    let token_of = |v: &str| -> Option<String> {
        let needle = format!("FaultKind::{v}");
        for l in &sf.lines[tlo..=thi.min(sf.lines.len() - 1)] {
            if let Some(p) = l.raw.find(&needle) {
                let after = &l.raw[p + needle.len()..];
                if let Some(q) = after.find('"') {
                    if after[..q].contains("=>") {
                        let rest = &after[q + 1..];
                        if let Some(q2) = rest.find('"') {
                            return Some(rest[..q2].to_string());
                        }
                    }
                }
            }
        }
        None
    };

    if adversity.is_none() {
        push_crate(
            ann,
            out,
            fi,
            "PL010",
            start + 1,
            "tests/adversity.rs not found next to src/ — cannot verify the adversity matrix \
             covers the fault catalog"
                .to_string(),
        );
    }

    for (v, jline) in &variants {
        let needle = format!("FaultKind::{v}");
        let mut consulted = false;
        'scan: for (gi, (_, gsf)) in files.iter().enumerate() {
            for (j, l) in gsf.lines.iter().enumerate() {
                if gsf.in_test[j] {
                    continue;
                }
                if gi == fi && excluded.iter().any(|&(s, e)| j >= s && j <= e) {
                    continue;
                }
                let mut from = 0;
                while let Some(p) = l.code[from..].find(&needle) {
                    let after = from + p + needle.len();
                    let next = l.code[after..].chars().next();
                    let boundary = !next.is_some_and(|c| c.is_alphanumeric() || c == '_');
                    if boundary {
                        consulted = true;
                        break 'scan;
                    }
                    from = after;
                }
            }
        }
        if !consulted {
            push_crate(
                ann,
                out,
                fi,
                "PL010",
                jline + 1,
                format!(
                    "FaultKind::{v} has no injection consult site in src/ — wire it into a \
                     step/net/ckpt dispatcher"
                ),
            );
        }
        match token_of(v) {
            None => push_crate(
                ann,
                out,
                fi,
                "PL010",
                jline + 1,
                format!("FaultKind::{v} has no canonical token in FaultKind::name()"),
            ),
            Some(tok) => {
                if let Some(text) = adversity {
                    if !text.contains(&tok) {
                        push_crate(
                            ann,
                            out,
                            fi,
                            "PL010",
                            jline + 1,
                            format!(
                                "FaultKind::{v} (`{tok}`) has no cell in tests/adversity.rs — \
                                 extend the adversity matrix"
                            ),
                        );
                    }
                }
            }
        }
    }
}

/// True when any line of the function enclosing `idx` mentions one of
/// `needles`. The span is approximated as [nearest `fn ` at-or-above,
/// next `fn ` below) — good enough because telemetry fields are assigned
/// in the same function body that reads the clock.
fn enclosing_fn_mentions(file: &SourceFile, idx: usize, needles: &[&str]) -> bool {
    let is_fn = |i: usize| file.lines[i].code.contains("fn ");
    let start = (0..=idx).rev().find(|&i| is_fn(i)).unwrap_or(0);
    let end = ((idx + 1)..file.lines.len()).find(|&i| is_fn(i)).unwrap_or(file.lines.len());
    file.lines[start..end]
        .iter()
        .any(|l| needles.iter().any(|n| l.code.contains(n)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn run(rel: &str, src: &str) -> Vec<(String, usize)> {
        check_file(rel, &lex(src))
            .into_iter()
            .map(|f| (f.rule.to_string(), f.line))
            .collect()
    }

    #[test]
    fn pl001_flags_hash_containers_only_in_scope() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(run("dp/engine.rs", src), vec![("PL001".into(), 1)]);
        assert_eq!(run("model.rs", src), vec![]);
        // prose and strings never match
        let prose = "// HashMap is banned here\nlet m = \"HashMap\";\n";
        assert_eq!(run("dp/engine.rs", prose), vec![]);
    }

    #[test]
    fn pl001_allow_with_reason_suppresses_within_window() {
        let src = "// lint: allow(PL001): single-key scratch map, never iterated\n\
                   // (continued justification)\n\
                   use std::collections::HashMap;\n";
        assert_eq!(run("dist/zero3.rs", src), vec![]);
    }

    #[test]
    fn bare_allow_is_reported_and_does_not_suppress() {
        let src = "// lint: allow(PL001)\nuse std::collections::HashMap;\n";
        let got = run("dp/engine.rs", src);
        assert_eq!(got, vec![("PL000".into(), 1), ("PL001".into(), 2)]);
    }

    #[test]
    fn pl002_flags_float_sums_but_not_length_arithmetic() {
        assert_eq!(
            run("dp/engine.rs", "let s = xs.iter().sum::<f32>();\n"),
            vec![("PL002".into(), 1)]
        );
        assert_eq!(run("dp/engine.rs", "let n: usize = xs.iter().map(Vec::len).sum();\n"), vec![]);
        // runtime/ is outside the reduce scope
        assert_eq!(run("runtime/client.rs", "let s = xs.iter().sum::<f32>();\n"), vec![]);
    }

    #[test]
    fn pl003_permits_telemetry_sinks_only() {
        let sink = "fn run(&self) {\n    let t0 = Instant::now();\n    \
                    self.execute_seconds.set(t0.elapsed().as_secs_f64());\n}\n";
        assert_eq!(run("runtime/executable.rs", sink), vec![]);
        let state = "fn seed(&self) -> u64 {\n    Instant::now().elapsed().as_nanos() as u64\n}\n";
        assert_eq!(run("dp/engine.rs", state), vec![("PL003".into(), 2)]);
    }

    #[test]
    fn pl004_skips_tests_and_honors_annotations() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n\
                   #[cfg(test)]\nmod tests {\n    fn t() { None::<u8>.unwrap(); }\n}\n";
        assert_eq!(run("checkpoint.rs", src), vec![("PL004".into(), 1)]);
        let annotated = "// lint: allow(PL004): documented invariant — x checked by caller\n\
                         fn f(x: Option<u8>) -> u8 { x.expect(\"checked\") }\n";
        assert_eq!(run("dist/model.rs", annotated), vec![]);
        // unwrap_or_else is not unwrap
        assert_eq!(run("dp/engine.rs", "let v = x.unwrap_or_else(Vec::new);\n"), vec![]);
    }

    #[test]
    fn pl005_requires_a_marker_within_the_window() {
        let bare = "let j = std::thread::Builder::new()\n    .name(\"w\".into())\n    \
                    .spawn(move || {})?;\n";
        assert_eq!(run("model.rs", bare), vec![("PL005".into(), 3)]);
        let marked = "// lint: thread: joined — Drop joins the handle.\n\
                      let j = std::thread::Builder::new()\n    .name(\"w\".into())\n    \
                      .spawn(move || {})?;\n";
        assert_eq!(run("model.rs", marked), vec![]);
        assert_eq!(run("model.rs", "scope.spawn(|| {});\n"), vec![]);
    }

    #[test]
    fn pl004_covers_faults_rs() {
        assert_eq!(
            run("faults.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n"),
            vec![("PL004".into(), 1)]
        );
    }

    // -- crate-wide rules -------------------------------------------------

    fn run_crate(files: &[(&str, &str)], adversity: Option<&str>) -> Vec<(String, String, usize)> {
        let lexed: Vec<(String, SourceFile)> =
            files.iter().map(|(r, s)| (r.to_string(), lex(s))).collect();
        let model = Model::build(&lexed);
        check_crate(&lexed, &model, adversity)
            .into_iter()
            .map(|(fi, f)| (f.rule.to_string(), lexed[fi].0.clone(), f.line))
            .collect()
    }

    #[test]
    fn pl006_fires_once_on_inverted_lock_order_with_both_witnesses() {
        let src = "fn ab(&self) {\n    let g = self.alpha.lock().unwrap();\n    \
                   let h = self.beta.lock().unwrap();\n}\n\
                   fn ba(&self) {\n    let g = self.beta.lock().unwrap();\n    \
                   let h = self.alpha.lock().unwrap();\n}\n";
        let got = run_crate(&[("locks.rs", src)], None);
        assert_eq!(got, vec![("PL006".into(), "locks.rs".into(), 3)]);
        let lexed = vec![("locks.rs".to_string(), lex(src))];
        let model = Model::build(&lexed);
        let msg = &check_crate(&lexed, &model, None)[0].1.message;
        assert!(msg.contains("`ab`") && msg.contains("`ba`"), "both witness paths: {msg}");
    }

    #[test]
    fn pl006_consistent_order_and_allow_are_silent() {
        let consistent = "fn ab(&self) {\n    let g = self.alpha.lock().unwrap();\n    \
                          let h = self.beta.lock().unwrap();\n}\n\
                          fn ab2(&self) {\n    let g = self.alpha.lock().unwrap();\n    \
                          let h = self.beta.lock().unwrap();\n}\n";
        assert_eq!(run_crate(&[("locks.rs", consistent)], None), vec![]);
        let allowed = "fn ab(&self) {\n    let g = self.alpha.lock().unwrap();\n    \
                       // lint: allow(PL006): shutdown path, beta uncontended by then\n    \
                       let h = self.beta.lock().unwrap();\n}\n\
                       fn ba(&self) {\n    let g = self.beta.lock().unwrap();\n    \
                       let h = self.alpha.lock().unwrap();\n}\n";
        assert_eq!(run_crate(&[("locks.rs", allowed)], None), vec![]);
    }

    #[test]
    fn pl006_sees_order_through_the_call_graph() {
        let src = "fn take_beta(&self) {\n    let g = self.beta.lock().unwrap();\n}\n\
                   fn ab(&self) {\n    let g = self.alpha.lock().unwrap();\n    \
                   self.take_beta();\n}\n\
                   fn ba(&self) {\n    let g = self.beta.lock().unwrap();\n    \
                   let h = self.alpha.lock().unwrap();\n}\n";
        let got = run_crate(&[("locks.rs", src)], None);
        assert_eq!(got, vec![("PL006".into(), "locks.rs".into(), 6)]);
    }

    #[test]
    fn pl007_flags_blocking_under_a_live_guard_in_scope_only() {
        let src = "fn pump(&self) {\n    let g = self.state.lock().unwrap();\n    \
                   let v = self.rx.recv();\n}\n";
        assert_eq!(run_crate(&[("dp/exec.rs", src)], None), vec![(
            "PL007".into(),
            "dp/exec.rs".into(),
            3
        )]);
        // outside dist/dp/pipeline the same shape is fine
        assert_eq!(run_crate(&[("runtime/exec.rs", src)], None), vec![]);
        // a guard confined to an inner block frees the recv
        let scoped = "fn pump(&self) {\n    {\n        let g = self.state.lock().unwrap();\n        \
                      g.touch();\n    }\n    let v = self.rx.recv();\n}\n";
        assert_eq!(run_crate(&[("dp/exec.rs", scoped)], None), vec![]);
    }

    #[test]
    fn pl007_follows_calls_that_transitively_block() {
        let src = "fn wait_one(rx: &Receiver<u8>) -> u8 {\n    rx.recv().unwrap()\n}\n\
                   fn pump(&self) {\n    let g = self.state.lock().unwrap();\n    \
                   let v = wait_one(&self.rx);\n}\n";
        let lexed = vec![("dp/exec.rs".to_string(), lex(src))];
        let model = Model::build(&lexed);
        let got = check_crate(&lexed, &model, None);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].1.line, 6);
        assert!(got[0].1.message.contains("wait_one"), "{}", got[0].1.message);
        assert!(got[0].1.message.contains("channel recv"), "{}", got[0].1.message);
    }

    #[test]
    fn pl008_flags_orphans_unbounded_and_magic_capacities() {
        let orphan = "fn f() {\n    let (tx, _) = mpsc::channel::<u8>();\n    keep(tx);\n}\n";
        assert_eq!(run_crate(&[("io.rs", orphan)], None), vec![(
            "PL008".into(),
            "io.rs".into(),
            2
        )]);
        let unbounded = "fn f() {\n    let (tx, rx) = mpsc::channel::<u8>();\n    keep(tx, rx);\n}\n";
        assert_eq!(run_crate(&[("dist/x.rs", unbounded)], None), vec![(
            "PL008".into(),
            "dist/x.rs".into(),
            2
        )]);
        // the same unbounded channel off the hot path is fine
        assert_eq!(run_crate(&[("telemetry.rs", unbounded)], None), vec![]);
        let magic = "fn f() {\n    let (tx, rx) = mpsc::sync_channel::<u8>(8);\n    keep(tx, rx);\n}\n";
        assert_eq!(run_crate(&[("dist/x.rs", magic)], None), vec![(
            "PL008".into(),
            "dist/x.rs".into(),
            2
        )]);
        let named = "const DEPTH: usize = 8;\n\
                     fn f(n: usize) {\n    let (tx, rx) = mpsc::sync_channel::<u8>(DEPTH);\n    \
                     let (jx, jr) = mpsc::sync_channel::<u8>(n.max(1));\n    keep(tx, rx, jx, jr);\n}\n";
        assert_eq!(run_crate(&[("dist/x.rs", named)], None), vec![]);
    }

    #[test]
    fn pl008_requires_markers_on_draining_threads() {
        let bad = "fn f(&self) {\n    let (tx, rx) = mpsc::sync_channel::<u8>(self.depth.max(1));\n    \
                   std::thread::spawn(move || {\n        while let Ok(v) = rx.recv() {\n            \
                   handle(v);\n        }\n    });\n    keep(tx);\n}\n";
        assert_eq!(run_crate(&[("dist/x.rs", bad)], None), vec![(
            "PL008".into(),
            "dist/x.rs".into(),
            2
        )]);
        let good = "fn f(&self) {\n    let (tx, rx) = mpsc::sync_channel::<u8>(self.depth.max(1));\n    \
                    // lint: thread: joined — Drop joins via handle.\n    \
                    std::thread::spawn(move || {\n        while let Ok(v) = rx.recv() {\n            \
                    handle(v);\n        }\n    });\n    keep(tx);\n}\n";
        assert_eq!(run_crate(&[("dist/x.rs", good)], None), vec![]);
    }

    #[test]
    fn pl009_wants_wire_context_in_dist_net_only() {
        let bad = "fn send(&self) -> Result<()> {\n    bail!(\"connection refused\")\n}\n";
        assert_eq!(run_crate(&[("dist/net/wire.rs", bad)], None), vec![(
            "PL009".into(),
            "dist/net/wire.rs".into(),
            2
        )]);
        assert_eq!(run_crate(&[("dist/other.rs", bad)], None), vec![]);
        let good = "fn send(&self) -> Result<()> {\n    \
                    bail!(\"rank {} lost peer {}\", self.rank, peer)\n}\n";
        assert_eq!(run_crate(&[("dist/net/wire.rs", good)], None), vec![]);
        // multi-line spans count: the context may sit on a later line
        let multi = "fn send(&self) -> Result<()> {\n    ensure!(\n        ok,\n        \
                     \"bad frame from peer {peer}\"\n    );\n    Ok(())\n}\n";
        assert_eq!(run_crate(&[("dist/net/wire.rs", multi)], None), vec![]);
        let allowed = "fn send(&self) -> Result<()> {\n    \
                       // lint: allow(PL009): decoder-local; run_op attaches rank+seq\n    \
                       bail!(\"connection refused\")\n}\n";
        assert_eq!(run_crate(&[("dist/net/wire.rs", allowed)], None), vec![]);
    }

    const FAULTS_FIXTURE: &str = "pub enum FaultKind {\n    Straggle,\n    Abort,\n}\n\
        impl FaultKind {\n    pub fn name(&self) -> &'static str {\n        match self {\n            \
        FaultKind::Straggle => \"straggle\",\n            \
        FaultKind::Abort => \"abort\",\n        }\n    }\n}\n";

    #[test]
    fn pl010_wants_a_consult_site_outside_the_parser() {
        // only Straggle is consulted; name()'s own arms must not count
        let consult = "fn fire(k: &FaultKind) {\n    if let FaultKind::Straggle = k {\n        \
                       slow();\n    }\n}\n";
        let got = run_crate(
            &[("faults.rs", FAULTS_FIXTURE), ("runtime.rs", consult)],
            Some("straggle abort"),
        );
        assert_eq!(got, vec![("PL010".into(), "faults.rs".into(), 3)]);
    }

    #[test]
    fn pl010_wants_an_adversity_cell_per_variant() {
        let consult = "fn fire(k: &FaultKind) {\n    match k {\n        \
                       FaultKind::Straggle => slow(),\n        \
                       FaultKind::Abort => die(),\n    }\n}\n";
        let files = [("faults.rs", FAULTS_FIXTURE), ("runtime.rs", consult)];
        assert_eq!(run_crate(&files, Some("straggle abort")), vec![]);
        assert_eq!(run_crate(&files, Some("straggle only")), vec![(
            "PL010".into(),
            "faults.rs".into(),
            3
        )]);
        // no adversity file at all: one finding at the enum
        assert_eq!(run_crate(&files, None), vec![("PL010".into(), "faults.rs".into(), 1)]);
    }
}
