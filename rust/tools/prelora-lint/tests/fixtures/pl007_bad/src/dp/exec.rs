pub fn pump(&self) {
    let g = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let v = self.rx.recv();
    consume(&g, v);
}
