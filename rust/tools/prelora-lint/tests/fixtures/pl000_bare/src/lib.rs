// lint: allow(PL004)
pub fn noop() {}
