// Adversity matrix (fixture): one cell per fault token.
#[test]
fn straggle_cell() {}

#[test]
fn abort_cell() {}
