pub enum FaultKind {
    Straggle,
    Abort,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Straggle => "straggle",
            FaultKind::Abort => "abort",
        }
    }
}
