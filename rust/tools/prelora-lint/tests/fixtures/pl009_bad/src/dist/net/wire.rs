pub fn decode(buf: &[u8]) -> Result<Frame> {
    bail!("short frame");
}
