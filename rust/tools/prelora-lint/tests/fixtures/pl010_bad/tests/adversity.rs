// Adversity matrix (fixture): covers straggle only.
#[test]
fn straggle_cell() {}
