pub fn consult(k: &FaultKind) -> bool {
    matches!(k, FaultKind::Straggle)
}
