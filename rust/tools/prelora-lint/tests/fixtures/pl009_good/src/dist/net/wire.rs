pub fn decode(buf: &[u8], peer: u32) -> Result<Frame> {
    ensure!(
        buf.len() >= 4,
        "short frame from peer {peer}: {} bytes",
        buf.len()
    );
    parse(buf)
}
