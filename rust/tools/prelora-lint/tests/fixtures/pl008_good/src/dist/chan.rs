const JOB_DEPTH: usize = 4;

pub fn build() {
    let (job_tx, job_rx) = std::sync::mpsc::sync_channel(JOB_DEPTH);
    route(job_tx, job_rx);
}
