pub fn build() {
    let (job_tx, _) = std::sync::mpsc::sync_channel(JOB_DEPTH);
    let (msg_tx, msg_rx) = std::sync::mpsc::channel();
    let (out_tx, out_rx) = std::sync::mpsc::sync_channel(8);
    route(job_tx, msg_tx, msg_rx, out_tx, out_rx);
}
