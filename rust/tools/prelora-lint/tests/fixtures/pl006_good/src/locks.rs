pub fn alpha_then_beta(&self) {
    let g = self.alpha.lock().unwrap();
    let h = self.beta.lock().unwrap();
    use_both(&g, &h);
}

pub fn also_alpha_then_beta(&self) {
    let g = self.alpha.lock().unwrap();
    let h = self.beta.lock().unwrap();
    use_both(&h, &g);
}
