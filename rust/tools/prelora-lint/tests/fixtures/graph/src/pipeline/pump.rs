const DEPTH: usize = 2;

pub fn start(&self) {
    let (tx, rx) = std::sync::mpsc::sync_channel(DEPTH);
    // lint: thread: joined — Pump::stop joins pump-worker
    let h = std::thread::Builder::new()
        .name("pump-worker".into())
        .spawn(move || {
            while let Ok(v) = rx.recv() {
                consume(v);
            }
        });
    keep(tx, h);
}
