pub fn alpha_then_beta(&self) {
    let g = self.alpha.lock().unwrap();
    // lint: allow(PL006): shutdown-only path — beta is uncontended once
    // alpha is held here, proven by the teardown ordering test.
    let h = self.beta.lock().unwrap();
    use_both(&g, &h);
}

pub fn beta_then_alpha(&self) {
    let g = self.beta.lock().unwrap();
    let h = self.alpha.lock().unwrap();
    use_both(&h, &g);
}
