//! End-to-end fixtures for the crate-level rules (PL006–PL010), the
//! output formats, and the topology graph — each case is a tiny source
//! tree under `tests/fixtures/<case>/src` that the real binary lints.
//!
//! The `real_tree_*` tests at the bottom are the acceptance gate: the
//! shipped `rust/src` must stay clean under the full rule set, and the
//! emitted topology graph must name every marker-carrying thread.

use std::path::PathBuf;
use std::process::Command;

fn fixture(case: &str) -> String {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(case)
        .join("src")
        .to_string_lossy()
        .into_owned()
}

/// Run the binary; returns (stdout, stderr, exit code).
fn lint(args: &[&str]) -> (String, String, Option<i32>) {
    let out = Command::new(env!("CARGO_BIN_EXE_prelora-lint"))
        .args(args)
        .output()
        .expect("spawn prelora-lint");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.code(),
    )
}

fn run_case(case: &str) -> (String, Option<i32>) {
    let (out, err, code) = lint(&["--root", &fixture(case)]);
    assert!(err.is_empty(), "unexpected stderr for {case}:\n{err}");
    (out, code)
}

fn assert_clean(case: &str) {
    let (out, code) = run_case(case);
    assert_eq!(code, Some(0), "{case} should be clean:\n{out}");
    assert!(out.contains("prelora-lint: clean"), "{out}");
}

#[test]
fn pl006_fires_once_with_both_witness_paths() {
    let (out, code) = run_case("pl006_bad");
    assert_eq!(code, Some(1), "{out}");
    assert!(out.contains("PL006 src/locks.rs:3"), "{out}");
    assert!(out.contains("alpha_then_beta"), "{out}");
    assert!(out.contains("beta_then_alpha"), "{out}");
    assert_eq!(out.matches("PL006").count(), 1, "one finding per pair:\n{out}");
}

#[test]
fn pl006_consistent_order_is_silent() {
    assert_clean("pl006_good");
}

#[test]
fn pl006_reasoned_allow_suppresses() {
    assert_clean("pl006_allowed");
}

#[test]
fn pl007_flags_recv_under_a_live_guard() {
    let (out, code) = run_case("pl007_bad");
    assert_eq!(code, Some(1), "{out}");
    assert!(out.contains("PL007 src/dp/exec.rs:3"), "{out}");
    assert!(out.contains("channel recv"), "{out}");
}

#[test]
fn pl007_scoped_guard_is_silent() {
    assert_clean("pl007_good");
}

#[test]
fn pl008_flags_orphans_unbounded_and_magic_capacities() {
    let (out, code) = run_case("pl008_bad");
    assert_eq!(code, Some(1), "{out}");
    assert!(out.contains("PL008 src/dist/chan.rs:2"), "{out}");
    assert!(out.contains("no named owning receiver"), "{out}");
    assert!(out.contains("PL008 src/dist/chan.rs:3"), "{out}");
    assert!(out.contains("unbounded channel()"), "{out}");
    assert!(out.contains("PL008 src/dist/chan.rs:4"), "{out}");
    assert!(out.contains("name the bound as a constant"), "{out}");
}

#[test]
fn pl008_named_constant_bound_is_silent() {
    assert_clean("pl008_good");
}

#[test]
fn pl009_flags_context_free_wire_errors() {
    let (out, code) = run_case("pl009_bad");
    assert_eq!(code, Some(1), "{out}");
    assert!(out.contains("PL009 src/dist/net/wire.rs:2"), "{out}");
}

#[test]
fn pl009_multi_line_ensure_with_peer_is_silent() {
    assert_clean("pl009_good");
}

#[test]
fn pl010_flags_unconsulted_and_untested_variants() {
    let (out, code) = run_case("pl010_bad");
    assert_eq!(code, Some(1), "{out}");
    assert!(out.contains("PL010 src/faults.rs:3"), "{out}");
    assert!(out.contains("no injection consult site"), "{out}");
    assert!(out.contains("has no cell in tests/adversity.rs"), "{out}");
    assert!(!out.contains("FaultKind::Straggle has"), "covered variant flagged:\n{out}");
}

#[test]
fn pl010_closed_catalog_is_silent() {
    assert_clean("pl010_good");
}

#[test]
fn pl000_bare_allow_is_a_finding() {
    let (out, code) = run_case("pl000_bare");
    assert_eq!(code, Some(1), "{out}");
    assert!(out.contains("PL000 src/lib.rs:1"), "{out}");
    assert!(out.contains("without a reason"), "{out}");
}

#[test]
fn json_schema_is_stable() {
    let (out, _, code) = lint(&["--format", "json", "--root", &fixture("pl009_bad")]);
    assert_eq!(code, Some(1), "{out}");
    assert!(
        out.starts_with("{\"schema\":\"prelora-lint/1\",\"findings\":["),
        "schema header drifted:\n{out}"
    );
    assert!(out.contains("\"rule\":\"PL009\",\"file\":\"src/dist/net/wire.rs\""), "{out}");
    assert!(out.contains("\"line\":2,\"message\":\""), "{out}");
    assert!(out.trim_end().ends_with("\"count\":1}"), "{out}");

    let (out, _, code) = lint(&["--format", "json", "--root", &fixture("pl009_good")]);
    assert_eq!(code, Some(0), "{out}");
    assert!(out.trim_end().ends_with("\"count\":0}"), "{out}");
}

#[test]
fn github_format_emits_error_annotations() {
    let (out, _, code) = lint(&["--format", "github", "--root", &fixture("pl009_bad")]);
    assert_eq!(code, Some(1), "{out}");
    assert!(
        out.contains("::error file=rust/src/dist/net/wire.rs,line=2,title=PL009::"),
        "{out}"
    );

    let (out, _, _) =
        lint(&["--format", "github", "--path-prefix", "", "--root", &fixture("pl009_bad")]);
    assert!(
        out.contains("::error file=src/dist/net/wire.rs,line=2,title=PL009::"),
        "{out}"
    );
}

#[test]
fn graph_names_threads_channels_and_owners() {
    let (out, err, code) = lint(&["--graph", "--root", &fixture("graph")]);
    assert_eq!(code, Some(0), "{err}");
    for needle in
        ["digraph prelora_topology", "pump-worker", "[joined]", "fn start", "cap=DEPTH", "tx to rx"]
    {
        assert!(out.contains(needle), "missing {needle:?} in graph:\n{out}");
    }
    // The graph fixture is also a lint-clean tree: marked drain, named bound.
    assert_clean("graph");
}

#[test]
fn list_rules_covers_the_catalog() {
    let (out, _, code) = lint(&["--list-rules"]);
    assert_eq!(code, Some(0));
    for n in 1..=10 {
        let id = format!("PL{n:03}");
        assert!(out.contains(&id), "missing {id} in --list-rules:\n{out}");
    }
}

#[test]
fn usage_errors_exit_2() {
    let (_, err, code) = lint(&["--format", "yaml"]);
    assert_eq!(code, Some(2), "{err}");
    assert!(err.contains("--format"), "{err}");

    let (_, err, code) = lint(&["--bogus"]);
    assert_eq!(code, Some(2), "{err}");
    assert!(err.contains("unknown argument"), "{err}");
}

#[test]
fn real_tree_is_clean() {
    let (out, err, code) = lint(&[]);
    assert_eq!(code, Some(0), "rust/src has findings:\n{out}\n{err}");
    assert!(out.contains("prelora-lint: clean"), "{out}");
}

#[test]
fn real_tree_graph_names_every_marked_thread() {
    let (out, err, code) = lint(&["--graph"]);
    assert_eq!(code, Some(0), "{err}");
    for name in
        ["net-tx-r", "net-rx-r", "bucket-reduce", "reduce-stage", "data-prefetch", "dp-worker-"]
    {
        assert!(out.contains(name), "thread {name:?} missing from the topology graph:\n{out}");
    }
}
