//! Figure 7 reproduction: time, compute, and memory utilization.
//!
//! Paper: PreLoRA vs the full baseline over the whole training cycle —
//! 1.5x lower average epoch time, 3x throughput, ~20% lower GPU memory,
//! trainable parameters down to ~10%. We run both cycles on the scaled
//! model and report the same four bars plus the measured ratios:
//!
//! * `results/fig7.csv` — metric, baseline, prelora, ratio
//!
//! Our ratios come from a CPU-PJRT testbed (see DESIGN.md); the *shape*
//! (who wins, direction of every bar) is the reproduction target.
//!
//! ```text
//! cargo run --release --example fig7_resources [-- <model> <epochs>]
//! ```

use anyhow::Result;
use prelora::config::RunConfig;
use prelora::telemetry::recorder::CsvRecorder;
use prelora::trainer::Trainer;

const SCALE: f64 = 12.0; // Exp2 thresholds scaled as in fig4_strictness.rs

fn cycle(model: &str, epochs: usize, enabled: bool) -> Result<prelora::RunSummary> {
    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.run_name = if enabled { "prelora" } else { "baseline" }.into();
    cfg.train.epochs = epochs;
    cfg.train.data.train_samples = 768;
    cfg.train.data.val_samples = 128;
    cfg.train.data.noise = 1.5;
    cfg.train.data.fresh_per_epoch = true; // calibrated: irreducible error keeps the loss floor paper-like
    cfg.prelora.enabled = enabled;
    cfg.prelora.tau = 0.50 * SCALE;
    cfg.prelora.zeta = 2.50 * SCALE;
    cfg.prelora.warmup_epochs = 5;
    let mut t = Trainer::new(cfg)?;
    let s = t.run()?;
    // drop the trainer (and its PJRT client + thread pool) before the next
    // cycle: two live CPU clients oversubscribe the core and skew timings
    Ok(s)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map_or("vit-small", |s| s.as_str());
    let epochs: usize = args.get(1).map_or(36, |s| s.parse().expect("epochs"));

    let bs = cycle(model, epochs, false)?;
    let ps = cycle(model, epochs, true)?;

    let b_time = bs.by_phase["full"].mean_epoch_seconds;
    let b_tput = bs.by_phase["full"].mean_images_per_sec;
    let b_mem = bs.by_phase["full"].mean_memory_bytes;
    // PreLoRA cycle: averages over the whole run (all phases), as the
    // paper reports "average ... over the total training cycle", plus the
    // steady-state LoRA phase alone.
    let whole = |f: fn(&prelora::report::PhaseAggregate) -> f64| {
        let mut num = 0.0;
        let mut den = 0.0;
        for agg in ps.by_phase.values() {
            num += f(agg) * agg.epochs as f64;
            den += agg.epochs as f64;
        }
        num / den
    };
    let p_time = whole(|a| a.mean_epoch_seconds);
    let p_tput = whole(|a| a.mean_images_per_sec);
    let p_mem = whole(|a| a.mean_memory_bytes);
    let lora_phase = ps.by_phase.get("lora");

    let trainable_b = bs.trainable_full as f64;
    let trainable_p = ps.trainable_lora.map_or(trainable_b, |t| t as f64);

    let mut csv = CsvRecorder::create("results", "fig7", &["metric_id", "baseline", "prelora", "ratio"])?;
    let rows = [
        ("epoch_time_s", b_time, p_time, b_time / p_time),
        ("throughput_img_s", b_tput, p_tput, p_tput / b_tput),
        ("memory_bytes", b_mem, p_mem, 1.0 - p_mem / b_mem),
        ("trainable_params", trainable_b, trainable_p, trainable_p / trainable_b),
    ];
    println!("Fig7 (whole-cycle averages, {model}, {epochs} epochs):");
    println!("{:<20} {:>14} {:>14} {:>10}", "metric", "baseline", "prelora", "ratio");
    for (i, (name, b, p, r)) in rows.iter().enumerate() {
        println!("{name:<20} {b:>14.2} {p:>14.2} {r:>10.3}");
        csv.row(&[i as f64, *b, *p, *r])?;
    }
    if let Some(l) = lora_phase {
        println!("\nsteady-state LoRA phase alone:");
        println!(
            "  epoch time {:.2}s ({:.2}x vs baseline), {:.0} img/s ({:.2}x), mem saving {:.1}%",
            l.mean_epoch_seconds,
            b_time / l.mean_epoch_seconds,
            l.mean_images_per_sec,
            l.mean_images_per_sec / b_tput,
            (1.0 - l.mean_memory_bytes / b_mem) * 100.0
        );
    }
    println!(
        "\ntrainable params: {} -> {} ({:.1}% of full; paper: ~10%)",
        bs.trainable_full,
        ps.trainable_lora.unwrap_or(bs.trainable_full),
        100.0 * trainable_p / trainable_b
    );
    println!(
        "switch at {:?}, freeze at {:?}; see results/fig7.csv",
        ps.switch_epoch, ps.freeze_epoch
    );
    Ok(())
}
