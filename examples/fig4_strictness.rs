//! Table 1 + Figure 4 reproduction: strictness of the convergence test.
//!
//! Paper: three (tau, zeta) settings — Exp1 (1.0, 5.0), Exp2 (0.5, 2.5),
//! Exp3 (0.25, 1.0) — against the full baseline. Relaxed thresholds switch
//! earliest and gain the most speed (~40% vs ~28%) at a small loss cost;
//! strict thresholds preserve the loss curve. We run the scaled versions
//! of all four and emit:
//!
//! * `results/fig4_curves.csv`  — run, epoch, train_loss, train_acc,
//!                                val_loss, val_acc, epoch_seconds, phase_id
//! * `results/fig4_summary.csv` — run, switch_epoch, freeze_epoch,
//!                                mean_epoch_s, speedup_pct, final_loss
//!
//! Shape expectations: switch(Exp1) <= switch(Exp2) <= switch(Exp3);
//! speedup(Exp1) >= speedup(Exp3); final_loss(Exp1) >= final_loss(Exp3).
//!
//! ```text
//! cargo run --release --example fig4_strictness [-- <model> <epochs>]
//! ```

use anyhow::Result;
use prelora::config::{RunConfig, StrictnessPreset};
use prelora::telemetry::recorder::CsvRecorder;
use prelora::trainer::Trainer;

/// Scale Table 1's percentages for the small model: the scaled run's loss
/// and norms move in larger relative steps per epoch than ViT-Large's, so
/// thresholds are multiplied by a constant factor while keeping the
/// paper's strictness *ordering* and ratios.
const SCALE: f64 = 12.0;

fn run(cfg: RunConfig, label: &str, curves: &mut CsvRecorder) -> Result<(prelora::RunSummary, f64)> {
    let mut t = Trainer::new(cfg)?;
    let epochs = t.cfg.train.epochs;
    let mut total_s = 0.0;
    for _ in 0..epochs {
        let s = t.run_epoch()?;
        total_s += s.epoch_seconds;
        let phase_id = match s.phase {
            "full" => 0.0,
            "warmup" => 1.0,
            _ => 2.0,
        };
        curves.tagged_row(
            label,
            &[
                s.epoch as f64,
                s.train_loss,
                s.train_acc,
                s.val_loss,
                s.val_acc,
                s.epoch_seconds,
                phase_id,
            ],
        )?;
    }
    let summary = t.summary();
    eprintln!("[{label}] done: {}", summary.render());
    // drop the trainer before the next run (PJRT thread-pool hygiene)
    Ok((summary, total_s))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map_or("vit-small", |s| s.as_str());
    let epochs: usize = args.get(1).map_or(36, |s| s.parse().expect("epochs"));

    let base_cfg = |name: &str| {
        let mut cfg = RunConfig::default();
        cfg.model = model.into();
        cfg.run_name = name.into();
        cfg.train.epochs = epochs;
    cfg.train.data.train_samples = 768;
    cfg.train.data.val_samples = 128;
    cfg.train.data.noise = 1.5;
    cfg.train.data.fresh_per_epoch = true; // calibrated: irreducible error keeps the loss floor paper-like
        cfg.prelora.windows = 3;
        cfg.prelora.window_epochs = 3;
        cfg.prelora.warmup_epochs = 5;
        cfg
    };

    let mut curves = CsvRecorder::create(
        "results",
        "fig4_curves",
        &["run", "epoch", "train_loss", "train_acc", "val_loss", "val_acc", "epoch_seconds", "phase"],
    )?;
    let mut summary = CsvRecorder::create(
        "results",
        "fig4_summary",
        &["run", "switch_epoch", "freeze_epoch", "mean_epoch_s", "speedup_pct", "final_loss"],
    )?;

    // full baseline
    let mut cfg = base_cfg("baseline");
    cfg.prelora.enabled = false;
    let (baseline_summary, base_total) = run(cfg, "baseline", &mut curves)?;
    let base_mean = base_total / epochs as f64;

    println!("Table 1 (scaled x{SCALE}):");
    let mut results = Vec::new();
    for preset in StrictnessPreset::all() {
        let label = format!("{preset:?}").to_lowercase();
        let (tau, zeta) = preset.thresholds();
        println!("  {label}: tau={:.2}% zeta={:.2}%", tau * SCALE, zeta * SCALE);
        let mut cfg = base_cfg(&label);
        cfg.prelora = cfg.prelora.with_preset(preset);
        cfg.prelora.tau *= SCALE;
        cfg.prelora.zeta *= SCALE;
        let (s, total) = run(cfg, &label, &mut curves)?;
        let mean = total / epochs as f64;
        let speedup = (1.0 - mean / base_mean) * 100.0;
        summary.tagged_row(
            &label,
            &[
                s.switch_epoch.map_or(-1.0, |e| e as f64),
                s.freeze_epoch.map_or(-1.0, |e| e as f64),
                mean,
                speedup,
                s.final_train_loss,
            ],
        )?;
        results.push((label, s.switch_epoch, speedup, s.final_train_loss));
    }
    summary.tagged_row("baseline", &[-1.0, -1.0, base_mean, 0.0, baseline_summary.final_train_loss])?;

    println!("\nFig4 shape check (relaxed -> strict):");
    for (label, sw, sp, fl) in &results {
        println!(
            "  {label}: switch={:?} speedup={sp:.1}% final_loss={fl:.4}",
            sw
        );
    }
    println!("(expect: switch epochs non-decreasing, speedups non-increasing)");
    println!("series written to results/fig4_*.csv");
    Ok(())
}
