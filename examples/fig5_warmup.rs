//! Figure 5 + Figure 6 reproduction: effect of the warmup window size w.
//!
//! Paper: with Exp2 thresholds fixed, w in {5, 10, 15} — (5a) loss curves
//! vs the baseline, (5b) epoch-time speedup (shorter warmup => earlier
//! gains), (6a) base-model Query weight norms grow longer under larger w,
//! (6b) LoRA Query norms end smaller under larger w (the base absorbs the
//! updates). Emits:
//!
//! * `results/fig5_loss.csv`       — run, epoch, train_loss
//! * `results/fig5_epoch_time.csv` — run, epoch, epoch_seconds, phase_id
//! * `results/fig6_base_norms.csv` — run, epoch, base query norm
//! * `results/fig6_lora_norms.csv` — run, epoch, lora query norm
//!
//! ```text
//! cargo run --release --example fig5_warmup [-- <model> <epochs>]
//! ```

use anyhow::Result;
use prelora::config::{RunConfig, StrictnessPreset};
use prelora::telemetry::recorder::CsvRecorder;
use prelora::trainer::Trainer;

const SCALE: f64 = 12.0; // see fig4_strictness.rs

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map_or("vit-small", |s| s.as_str());
    let epochs: usize = args.get(1).map_or(36, |s| s.parse().expect("epochs"));
    // paper sweeps w = 5, 10, 15; scaled runs keep the same values
    let windows: Vec<usize> = args
        .get(2)
        .map(|s| s.split(',').map(|x| x.parse().expect("w")).collect())
        .unwrap_or_else(|| vec![4, 8, 12]); // paper's 5/10/15 at ~0.8x epoch scale (1:2:3 ratio kept)

    let mut loss = CsvRecorder::create("results", "fig5_loss", &["run", "epoch", "train_loss"])?;
    let mut time = CsvRecorder::create(
        "results",
        "fig5_epoch_time",
        &["run", "epoch", "epoch_seconds", "phase"],
    )?;
    let mut base_norms =
        CsvRecorder::create("results", "fig6_base_norms", &["run", "epoch", "query_norm"])?;
    let mut lora_norms =
        CsvRecorder::create("results", "fig6_lora_norms", &["run", "epoch", "query_norm"])?;

    let make_cfg = |name: &str, w: Option<usize>| {
        let mut cfg = RunConfig::default();
        cfg.model = model.into();
        cfg.run_name = name.into();
        cfg.train.epochs = epochs;
    cfg.train.data.train_samples = 768;
    cfg.train.data.val_samples = 128;
    cfg.train.data.noise = 1.5;
    cfg.train.data.fresh_per_epoch = true; // calibrated: irreducible error keeps the loss floor paper-like
        match w {
            Some(w) => {
                cfg.prelora = cfg.prelora.with_preset(StrictnessPreset::Exp2);
                cfg.prelora.tau *= SCALE;
                cfg.prelora.zeta *= SCALE;
                cfg.prelora.warmup_epochs = w;
            }
            None => cfg.prelora.enabled = false,
        }
        cfg
    };

    let mut runs: Vec<(String, Option<usize>)> = vec![("baseline".into(), None)];
    runs.extend(windows.iter().map(|&w| (format!("w{w}"), Some(w))));

    let mut freeze_epochs = Vec::new();
    for (label, w) in &runs {
        let mut t = Trainer::new(make_cfg(label, *w))?;
        for _ in 0..epochs {
            let s = t.run_epoch()?;
            let phase_id = match s.phase {
                "full" => 0.0,
                "warmup" => 1.0,
                _ => 2.0,
            };
            loss.tagged_row(label, &[s.epoch as f64, s.train_loss])?;
            time.tagged_row(label, &[s.epoch as f64, s.epoch_seconds, phase_id])?;
            let q = t.history().last().unwrap().module_mean("query").unwrap_or(0.0);
            base_norms.tagged_row(label, &[s.epoch as f64, q])?;
            if let Some(lq) = t.lora_module_norm("query") {
                lora_norms.tagged_row(label, &[s.epoch as f64, lq])?;
            }
        }
        let s = t.summary();
        eprintln!("[{label}] {}", s.render());
        freeze_epochs.push((label.clone(), s.switch_epoch, s.freeze_epoch));
    }

    println!("\nFig5/6 shape check:");
    for (label, sw, fr) in &freeze_epochs {
        println!("  {label}: switch={sw:?} freeze={fr:?}");
    }
    println!("(expect: same switch epoch across w — thresholds identical —");
    println!(" and freeze = switch + w, so smaller w gains speed earlier;");
    println!(" fig6: larger w => larger final base norms, smaller lora norms)");
    println!("series written to results/fig5_*.csv, results/fig6_*.csv");
    Ok(())
}
