//! Ablation beyond the paper: design choices DESIGN.md calls out.
//!
//! 1. Convergence strategy — the paper's windowed thresholds (Algorithm 1)
//!    vs the Welch t-test of Dahal et al. (HPT), which the related-work
//!    section argues is heavier than needed. Both run single-model here;
//!    we compare *when* they fire and the resulting loss.
//! 2. Rank assignment — Algorithm 2's dynamic per-layer ranks vs a uniform
//!    rank with a comparable parameter budget.
//!
//! * `results/ablation_strategies.csv` — run, switch, freeze, final_loss,
//!   trainable_params, mean_epoch_s
//!
//! ```text
//! cargo run --release --example ablation_strategies [-- <model> <epochs>]
//! ```

use anyhow::Result;
use prelora::config::{ConvergenceStrategyKind, RunConfig};
use prelora::telemetry::recorder::CsvRecorder;
use prelora::trainer::Trainer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map_or("vit-small", |s| s.as_str());
    let epochs: usize = args.get(1).map_or(24, |s| s.parse().expect("epochs"));

    let base_cfg = |name: &str| {
        let mut cfg = RunConfig::default();
        cfg.model = model.into();
        cfg.run_name = name.into();
        cfg.train.epochs = epochs;
    cfg.train.data.train_samples = 768;
    cfg.train.data.val_samples = 128;
    cfg.train.data.noise = 1.5;
    cfg.train.data.fresh_per_epoch = true; // calibrated: irreducible error keeps the loss floor paper-like
        cfg.prelora.tau = 6.0; // scaled Exp2
        cfg.prelora.zeta = 25.0;
        cfg.prelora.warmup_epochs = 5;
        cfg
    };

    let mut csv = CsvRecorder::create(
        "results",
        "ablation_strategies",
        &["run", "switch", "freeze", "final_loss", "trainable_params", "mean_epoch_s"],
    )?;

    let variants: Vec<(String, RunConfig)> = vec![
        ("alg1_dynamic".into(), base_cfg("alg1_dynamic")),
        (
            "ttest_dynamic".into(),
            {
                let mut c = base_cfg("ttest_dynamic");
                c.prelora.strategy = ConvergenceStrategyKind::WelchTTest;
                c.prelora.ttest_alpha = 0.05;
                c
            },
        ),
        (
            "alg1_uniform".into(),
            {
                let mut c = base_cfg("alg1_uniform");
                c.prelora.dynamic_ranks = false;
                c.prelora.uniform_rank = 8;
                c
            },
        ),
    ];

    for (label, cfg) in variants {
        let mut t = Trainer::new(cfg)?;
        let mut total_s = 0.0;
        for _ in 0..epochs {
            total_s += t.run_epoch()?.epoch_seconds;
        }
        let s = t.summary();
        eprintln!("[{label}] {}", s.render());
        csv.tagged_row(
            &label,
            &[
                s.switch_epoch.map_or(-1.0, |e| e as f64),
                s.freeze_epoch.map_or(-1.0, |e| e as f64),
                s.final_train_loss,
                s.trainable_lora.map_or(-1.0, |t| t as f64),
                total_s / epochs as f64,
            ],
        )?;
        if let Some(h) = &s.rank_histogram {
            println!("  {label} ranks: {h:?}");
        }
    }
    println!("results/ablation_strategies.csv written");
    Ok(())
}
