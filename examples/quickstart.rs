//! Quickstart: the smallest complete PreLoRA run.
//!
//! Trains vit-micro from scratch on the synthetic corpus, lets the
//! partial convergence test (Algorithm 1) trigger the switch, assigns
//! per-layer ranks (Algorithm 2), runs the warmup window and finishes in
//! LoRA-only mode — printing the run summary at the end.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use prelora::config::RunConfig;
use prelora::trainer::Trainer;

fn main() -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.model = "vit-micro".into();
    cfg.run_name = "quickstart".into();
    cfg.train.epochs = 24;
    cfg.train.data.train_samples = 512;
    cfg.train.data.val_samples = 128;
    // micro-scale thresholds: the tiny model's loss moves in larger
    // relative steps than ViT-Large's, so Table 1's percentages are scaled
    cfg.prelora.tau = 3.0;
    cfg.prelora.zeta = 12.0;
    cfg.prelora.windows = 2;
    cfg.prelora.window_epochs = 2;
    cfg.prelora.warmup_epochs = 4;

    let mut trainer = Trainer::new(cfg)?;
    let summary = trainer.run()?;
    println!("{}", summary.render());

    // the run must have completed the Full -> Warmup -> LoraOnly lifecycle
    if summary.freeze_epoch.is_none() {
        eprintln!("note: run ended before the LoRA-only phase; raise epochs or relax tau/zeta");
    }
    Ok(())
}
