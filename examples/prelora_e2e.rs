//! End-to-end driver: the full system on the largest CPU-feasible model.
//!
//! Trains the `vit-base-sim` stand-in (6.4M params — the scaled ViT-Large
//! substitute, see DESIGN.md) from scratch through the complete PreLoRA
//! lifecycle with a multi-worker data-parallel engine, logging the loss
//! curve and finishing with the paper's headline metrics. This is the
//! proof that all layers compose: Pallas kernels (L1) inside the AOT HLO
//! (L2) driven by the Rust coordinator, optimizer, convergence test, rank
//! assignment and all-reduce (L3), with Python nowhere on the path.
//!
//! The run is deliberately **preempted halfway**: the first trainer is
//! dropped at the midpoint after saving a v3 checkpoint, and a second
//! trainer resumes it via `Trainer::restore` — the same path as
//! `prelora train --resume <ckpt>` — proving end-to-end that the phase
//! machine, history and optimizer state continue mid-trajectory
//! (spot-instance training, made literal).
//!
//! * `results/e2e_loss.csv`  — epoch, step, train_loss
//! * `results/e2e_epochs.csv` — per-epoch stats
//!
//! ```text
//! cargo run --release --example prelora_e2e [-- <model> <epochs> <workers>]
//! ```

use anyhow::Result;
use prelora::config::RunConfig;
use prelora::telemetry::recorder::CsvRecorder;
use prelora::trainer::Trainer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map_or("vit-base-sim", |s| s.as_str());
    let epochs: usize = args.get(1).map_or(10, |s| s.parse().expect("epochs"));
    let workers: usize = args.get(2).map_or(2, |s| s.parse().expect("workers"));

    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.run_name = "e2e".into();
    cfg.train.epochs = epochs;
    cfg.train.dp.workers = workers;
    cfg.train.dp.allreduce = "ring".into();
    cfg.train.data.train_samples = 512;
    cfg.train.data.val_samples = 128;
    cfg.train.data.noise = 1.5;
    cfg.train.data.fresh_per_epoch = true;
    // scaled Exp2 thresholds (see fig4_strictness.rs)
    cfg.prelora.tau = 4.0;
    cfg.prelora.zeta = 20.0;
    cfg.prelora.warmup_epochs = 4;
    cfg.prelora.windows = 2;
    cfg.prelora.window_epochs = 2;

    eprintln!(
        "e2e: model={model} epochs={epochs} workers={workers} (ring all-reduce)"
    );
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(cfg.clone())?;
    eprintln!(
        "setup done in {:.1}s ({} base params, {} adapters)",
        t0.elapsed().as_secs_f64(),
        trainer.manifest.base.size,
        trainer.manifest.adapters.len()
    );
    // simulate a preemption at the midpoint: save, drop, resume
    let preempt_at = (epochs / 2).max(1);
    let ckpt_path = std::path::Path::new("results").join("e2e_mid.ckpt");

    let mut epochs_csv = CsvRecorder::create(
        "results",
        "e2e_epochs",
        &[
            "epoch",
            "phase",
            "train_loss",
            "train_acc",
            "val_loss",
            "val_acc",
            "epoch_seconds",
            "images_per_sec",
            "trainable_params",
            "memory_bytes",
            "opt_state_bytes_per_worker",
            "grad_bytes_per_worker",
        ],
    )?;
    for epoch in 0..epochs {
        if epoch == preempt_at {
            trainer.checkpoint().save(&ckpt_path)?;
            drop(trainer);
            eprintln!(
                "--- preempted after epoch {} (checkpoint {}); resuming in a fresh trainer ---",
                preempt_at - 1,
                ckpt_path.display()
            );
            // the `prelora train --resume <ckpt>` path: fresh trainer,
            // restore, continue mid-trajectory
            let restored = prelora::trainer::Checkpoint::load(&ckpt_path)?;
            trainer = Trainer::new(cfg.clone())?;
            trainer.restore(&restored)?;
            anyhow::ensure!(
                trainer.stats.len() == preempt_at,
                "resume must restore the completed epochs' stats"
            );
            eprintln!("resumed at epoch {} ({})", preempt_at, trainer.phase());
        }
        let s = trainer.run_epoch()?;
        anyhow::ensure!(s.epoch == epoch, "epoch cursor drifted across the resume");
        let phase_id = match s.phase {
            "full" => 0.0,
            "warmup" => 1.0,
            _ => 2.0,
        };
        epochs_csv.row(&[
            s.epoch as f64,
            phase_id,
            s.train_loss,
            s.train_acc,
            s.val_loss,
            s.val_acc,
            s.epoch_seconds,
            s.images_per_sec,
            s.trainable_params as f64,
            s.memory_model_bytes as f64,
            s.opt_state_bytes_per_worker as f64,
            s.grad_bytes_per_worker as f64,
        ])?;
        eprintln!(
            "epoch {:>3} [{}] loss {:.4} acc {:.3} val {:.4}/{:.3} {:.1}s {:.0} img/s",
            s.epoch, s.phase, s.train_loss, s.train_acc, s.val_loss, s.val_acc,
            s.epoch_seconds, s.images_per_sec
        );
    }

    let summary = trainer.summary();
    println!("{}", summary.render());
    std::fs::write("results/e2e_summary.json", summary.to_json())?;
    println!("total wall time: {:.1}s", t0.elapsed().as_secs_f64());
    println!("loss curve in results/e2e_epochs.csv, summary in results/e2e_summary.json");

    // e2e acceptance: must have learned and completed the lifecycle
    let first = trainer.stats[0].train_loss;
    let last = trainer.stats.last().unwrap().train_loss;
    anyhow::ensure!(last < first, "e2e run did not learn ({first} -> {last})");
    if summary.freeze_epoch.is_some() {
        println!("lifecycle complete: Full -> Warmup -> LoraOnly ✓");
    } else {
        println!("note: lifecycle incomplete (no freeze) — raise epochs or relax thresholds");
    }
    Ok(())
}
