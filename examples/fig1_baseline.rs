//! Figure 1 + Figure 3 reproduction: full-model pretraining telemetry.
//!
//! Paper: ViT-Large on ImageNet-1k, 300 epochs — (a) per-module weight
//! norms stabilize in the second half of training while (b) the training
//! cross-entropy loss keeps falling; Fig. 3 shows the per-layer Query
//! norms fanning out. We run the scaled baseline (PreLoRA disabled) and
//! emit the same three series:
//!
//! * `results/fig1_norms.csv`        — epoch, module, mean weight norm
//! * `results/fig1_loss.csv`         — epoch, train CE loss
//! * `results/fig3_query_layers.csv` — epoch, layer, Query weight norm
//!
//! The expected *shape*: norm deltas shrink well before the loss plateaus
//! — exactly the window the PreLoRA switch exploits.
//!
//! ```text
//! cargo run --release --example fig1_baseline [-- <model> <epochs>]
//! ```

use anyhow::Result;
use prelora::config::RunConfig;
use prelora::telemetry::recorder::CsvRecorder;
use prelora::trainer::Trainer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map_or("vit-small", |s| s.as_str());
    let epochs: usize = args.get(1).map_or(36, |s| s.parse().expect("epochs"));

    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.run_name = "fig1-baseline".into();
    cfg.train.epochs = epochs;
    cfg.train.data.train_samples = 512;
    cfg.train.data.val_samples = 128;
    cfg.train.data.noise = 1.5;
    cfg.train.data.fresh_per_epoch = true; // calibrated: irreducible error keeps the loss floor paper-like
    cfg.prelora.enabled = false; // pure full-parameter baseline

    let mut trainer = Trainer::new(cfg.clone())?;
    let mut norms = CsvRecorder::create(&cfg.results_dir, "fig1_norms", &["epoch", "module_id", "norm"])?;
    let mut norms_named =
        CsvRecorder::create(&cfg.results_dir, "fig1_norms_named", &["module", "epoch", "norm"])?;
    let mut loss = CsvRecorder::create(&cfg.results_dir, "fig1_loss", &["epoch", "train_loss"])?;
    let mut fig3 =
        CsvRecorder::create(&cfg.results_dir, "fig3_query_layers", &["epoch", "layer", "norm"])?;

    for _ in 0..epochs {
        let s = trainer.run_epoch()?;
        let snap = trainer.history().last().unwrap().clone();
        for (mi, (module, layers)) in snap.by_module.iter().enumerate() {
            let mean = layers.iter().sum::<f64>() / layers.len() as f64;
            norms.row(&[s.epoch as f64, mi as f64, mean])?;
            norms_named.tagged_row(module, &[s.epoch as f64, mean])?;
        }
        for (l, n) in snap.by_module["query"].iter().enumerate() {
            fig3.row(&[s.epoch as f64, l as f64, *n])?;
        }
        loss.row(&[s.epoch as f64, s.train_loss])?;
        eprintln!(
            "epoch {:>3} loss {:.4} acc {:.3} ({:.2}s)",
            s.epoch, s.train_loss, s.train_acc, s.epoch_seconds
        );
    }

    // Fig. 1's claim, checked numerically: late-phase norm drift is far
    // smaller than early-phase drift, while the loss is still moving.
    let h = trainer.history();
    let e = h.epochs();
    let drift = |module: &str, a: usize, b: usize| {
        let na = h.snapshot(a).module_mean(module).unwrap();
        let nb = h.snapshot(b).module_mean(module).unwrap();
        ((nb - na) / na * 100.0).abs()
    };
    let early = drift("query", 1, e / 4);
    let late = drift("query", 3 * e / 4, e - 1);
    let loss_late = (h.losses()[e - 1] - h.losses()[3 * e / 4]).abs();
    println!("\nFig1 shape check:");
    println!("  query norm drift early {early:.2}% vs late {late:.2}%  (expect early >> late)");
    println!("  loss still moving late: |dL| = {loss_late:.4}  (expect > 0)");
    println!("{}", trainer.summary().render());
    println!("series written to results/fig1_*.csv and results/fig3_query_layers.csv");
    Ok(())
}
